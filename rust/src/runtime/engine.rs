//! PJRT runtime engine: loads HLO-text artifacts, compiles them on the
//! CPU PJRT client, keeps variant weights resident as device buffers,
//! and executes forward passes from the Rust request path.
//!
//! HLO *text* is the interchange format (xla_extension 0.5.1 rejects
//! jax>=0.5 serialized protos with 64-bit instruction ids; the text
//! parser reassigns ids — see DESIGN.md).
//!
//! PJRT wrapper types hold raw pointers and are not `Send`; the engine
//! is therefore confined to whichever thread created it.  Cross-thread
//! serving goes through [`super::pool::ExecutorPool`].

use std::collections::HashMap;
use std::time::{Duration, Instant};

use crate::anyhow;
use crate::util::error::{Context, Result};

use super::manifest::Manifest;
use super::xla_stub as xla;
use super::weights::{self, VariantWeights};

/// A compiled artifact with its resident weight buffers.
struct LoadedVariant {
    exe: xla::PjRtLoadedExecutable,
    weight_bufs: Vec<xla::PjRtBuffer>,
    hidden: usize,
    batch: usize,
}

/// The runtime engine.  One per executor thread.
pub struct Engine {
    client: xla::PjRtClient,
    pub manifest: Manifest,
    variants: HashMap<(String, usize), LoadedVariant>,
    predictor: Option<xla::PjRtLoadedExecutable>,
}

impl Engine {
    /// Create a CPU PJRT engine over an artifact directory.
    pub fn new(artifact_dir: impl AsRef<std::path::Path>) -> Result<Engine> {
        let manifest = Manifest::load(artifact_dir)?;
        let client = xla::PjRtClient::cpu().map_err(to_anyhow)?;
        Ok(Engine { client, manifest, variants: HashMap::new(), predictor: None })
    }

    fn compile_file(&self, rel: &str) -> Result<xla::PjRtLoadedExecutable> {
        let path = self.manifest.abs_path(rel);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
        )
        .map_err(to_anyhow)
        .with_context(|| format!("parsing {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        self.client.compile(&comp).map_err(to_anyhow)
    }

    /// Ensure (key, batch) is compiled with weights staged on device.
    pub fn load_variant(&mut self, key: &str, batch: usize) -> Result<()> {
        if self.variants.contains_key(&(key.to_string(), batch)) {
            return Ok(());
        }
        let art = self
            .manifest
            .variant(key, batch)
            .ok_or_else(|| anyhow!("no artifact for {key} b={batch}"))?
            .clone();
        let exe = self.compile_file(&art.path)?;
        let w: VariantWeights = weights::make_params(key, art.hidden, art.layers);
        let mut weight_bufs = Vec::with_capacity(w.tensors.len());
        for (t, shape) in w.tensors.iter().zip(&w.shapes) {
            let buf = self
                .client
                .buffer_from_host_buffer::<f32>(t, shape, None)
                .map_err(to_anyhow)?;
            weight_bufs.push(buf);
        }
        self.variants.insert(
            (key.to_string(), batch),
            LoadedVariant { exe, weight_bufs, hidden: art.hidden, batch },
        );
        Ok(())
    }

    /// Execute a forward pass.  `input` is row-major `[batch, hidden]`.
    /// Returns (output, device wall time).
    pub fn execute_variant(
        &mut self,
        key: &str,
        batch: usize,
        input: &[f32],
    ) -> Result<(Vec<f32>, Duration)> {
        self.load_variant(key, batch)?;
        let lv = &self.variants[&(key.to_string(), batch)];
        if input.len() != lv.batch * lv.hidden {
            return Err(anyhow!(
                "input len {} != {}x{}",
                input.len(),
                lv.batch,
                lv.hidden
            ));
        }
        let in_buf = self
            .client
            .buffer_from_host_buffer::<f32>(input, &[lv.batch, lv.hidden], None)
            .map_err(to_anyhow)?;
        let mut args: Vec<&xla::PjRtBuffer> = Vec::with_capacity(1 + lv.weight_bufs.len());
        args.push(&in_buf);
        args.extend(lv.weight_bufs.iter());
        let t0 = Instant::now();
        let out = lv.exe.execute_b(&args).map_err(to_anyhow)?;
        let lit = out[0][0].to_literal_sync().map_err(to_anyhow)?;
        let dt = t0.elapsed();
        // aot.py lowers with return_tuple=True → 1-tuple.
        let inner = lit.to_tuple1().map_err(to_anyhow)?;
        Ok((inner.to_vec::<f32>().map_err(to_anyhow)?, dt))
    }

    /// Run the manifest's deterministic numerics check for a variant at
    /// batch 1: returns (measured sum, expected sum).
    pub fn check_variant(&mut self, key: &str) -> Result<(f64, f64)> {
        let art = self
            .manifest
            .variant(key, 1)
            .ok_or_else(|| anyhow!("no b=1 artifact for {key}"))?;
        let expected = art.check_sum_b1;
        let hidden = art.hidden;
        let x = weights::check_input(hidden, 1);
        let (y, _) = self.execute_variant(key, 1, &x)?;
        Ok((y.iter().map(|&v| v as f64).sum(), expected))
    }

    /// Compile the LSTM predictor artifact.
    pub fn load_predictor(&mut self) -> Result<()> {
        if self.predictor.is_some() {
            return Ok(());
        }
        let art = self
            .manifest
            .predictor
            .as_ref()
            .ok_or_else(|| anyhow!("manifest has no predictor artifact"))?
            .clone();
        self.predictor = Some(self.compile_file(&art.path)?);
        Ok(())
    }

    /// Predict the next-horizon max RPS from a 120-second load window.
    pub fn predict(&mut self, window: &[f32]) -> Result<f32> {
        self.load_predictor()?;
        let history = self
            .manifest
            .predictor
            .as_ref()
            .map(|p| p.history)
            .unwrap_or(crate::predictor::HISTORY);
        if window.len() != history {
            return Err(anyhow!("window len {} != {history}", window.len()));
        }
        let exe = self.predictor.as_ref().unwrap();
        let lit = xla::Literal::vec1(window)
            .reshape(&[1, history as i64])
            .map_err(to_anyhow)?;
        let out = exe.execute::<xla::Literal>(&[lit]).map_err(to_anyhow)?;
        let res = out[0][0].to_literal_sync().map_err(to_anyhow)?;
        let inner = res.to_tuple1().map_err(to_anyhow)?;
        let v = inner.to_vec::<f32>().map_err(to_anyhow)?;
        Ok(v[0])
    }

    /// Number of compiled variants (cache introspection).
    pub fn loaded_count(&self) -> usize {
        self.variants.len()
    }
}

/// xla::Error is not std::error::Error-compatible with our error type
/// directly; stringify.
fn to_anyhow(e: xla::Error) -> crate::util::error::Error {
    anyhow!("{e:?}")
}
