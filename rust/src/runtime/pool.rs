//! Executor pool: PJRT wrapper types are not `Send`, so each executor
//! thread owns its own [`Engine`] (its own PJRT client + compiled
//! executables) and work arrives over channels.  The live serving
//! engine's replicas submit batch executions here; the adapter submits
//! LSTM predictions.
//!
//! Each worker owns its OWN channel — submitters round-robin over the
//! per-worker senders with one atomic counter, so there is no shared
//! `Mutex<Receiver>` for every job to funnel through (the old design
//! serialized all submissions AND all idle workers on one lock).

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

use crate::anyhow;
use crate::util::error::Result;

use super::engine::Engine;

/// A unit of work for an executor thread.
enum Job {
    ExecVariant {
        key: String,
        batch: usize,
        input: Vec<f32>,
        reply: Sender<Result<(Vec<f32>, Duration)>>,
    },
    Predict {
        window: Vec<f32>,
        reply: Sender<Result<f32>>,
    },
    Warm {
        key: String,
        batch: usize,
        reply: Sender<Result<()>>,
    },
    Shutdown,
}

/// Handle to a pool of executor threads, each owning one [`Engine`]
/// and one private job channel.
pub struct ExecutorPool {
    txs: Vec<Sender<Job>>,
    /// Round-robin cursor over `txs`.
    next: AtomicUsize,
    handles: Vec<JoinHandle<()>>,
}

/// Answer every queued job with an error: an executor whose engine
/// failed to initialize must not leave submitters blocked on a reply
/// that will never come.
fn drain_with_error(rx: &Receiver<Job>) {
    while let Ok(job) = rx.recv() {
        match job {
            Job::ExecVariant { reply, .. } => {
                let _ = reply.send(Err(anyhow!("executor engine failed to initialize")));
            }
            Job::Predict { reply, .. } => {
                let _ = reply.send(Err(anyhow!("executor engine failed to initialize")));
            }
            Job::Warm { reply, .. } => {
                let _ = reply.send(Err(anyhow!("executor engine failed to initialize")));
            }
            Job::Shutdown => return,
        }
    }
}

impl ExecutorPool {
    /// Spawn `n_threads` executors over `artifact_dir`.
    pub fn new(artifact_dir: &str, n_threads: usize) -> Result<ExecutorPool> {
        let mut txs = Vec::new();
        let mut handles = Vec::new();
        for i in 0..n_threads.max(1) {
            let (tx, rx) = channel::<Job>();
            txs.push(tx);
            let dir = artifact_dir.to_string();
            handles.push(
                std::thread::Builder::new()
                    .name(format!("ipa-exec-{i}"))
                    .spawn(move || {
                        let mut engine = match Engine::new(&dir) {
                            Ok(e) => e,
                            Err(e) => {
                                crate::log_error!("pool", "engine init failed: {e:#}");
                                drain_with_error(&rx);
                                return;
                            }
                        };
                        // the worker owns its receiver — no lock
                        loop {
                            match rx.recv() {
                                Ok(Job::ExecVariant { key, batch, input, reply }) => {
                                    let r = engine.execute_variant(&key, batch, &input);
                                    let _ = reply.send(r);
                                }
                                Ok(Job::Predict { window, reply }) => {
                                    let _ = reply.send(engine.predict(&window));
                                }
                                Ok(Job::Warm { key, batch, reply }) => {
                                    let _ = reply.send(engine.load_variant(&key, batch));
                                }
                                Ok(Job::Shutdown) | Err(_) => return,
                            }
                        }
                    })
                    .expect("spawn executor"),
            );
        }
        Ok(ExecutorPool { txs, next: AtomicUsize::new(0), handles })
    }

    /// Submit one job to the next worker, round-robin.
    fn submit(&self, job: Job) -> Result<()> {
        let i = self.next.fetch_add(1, Ordering::Relaxed) % self.txs.len();
        self.txs[i].send(job).map_err(|_| anyhow!("pool closed"))
    }

    /// Synchronous batched forward pass on some executor.
    pub fn execute(&self, key: &str, batch: usize, input: Vec<f32>) -> Result<(Vec<f32>, Duration)> {
        let (reply, rx) = channel();
        self.submit(Job::ExecVariant { key: key.to_string(), batch, input, reply })?;
        rx.recv().map_err(|_| anyhow!("executor died"))?
    }

    /// Synchronous LSTM prediction.
    pub fn predict(&self, window: Vec<f32>) -> Result<f32> {
        let (reply, rx) = channel();
        self.submit(Job::Predict { window, reply })?;
        rx.recv().map_err(|_| anyhow!("executor died"))?
    }

    /// Pre-compile (key, batch) on one executor (first-touch warmup).
    pub fn warm(&self, key: &str, batch: usize) -> Result<()> {
        let (reply, rx) = channel();
        self.submit(Job::Warm { key: key.to_string(), batch, reply })?;
        rx.recv().map_err(|_| anyhow!("executor died"))?
    }

    /// A `Send` closure for [`crate::predictor::LstmPredictor`] that
    /// routes predictions through this pool.
    pub fn lstm_closure(self: &Arc<Self>) -> Box<dyn FnMut(&[f32]) -> f32 + Send> {
        let pool = Arc::clone(self);
        Box::new(move |window: &[f32]| match pool.predict(window.to_vec()) {
            Ok(v) => v,
            Err(e) => {
                crate::log_warn!("pool", "lstm predict failed: {e:#}");
                0.0
            }
        })
    }

    pub fn shutdown(mut self) {
        for tx in &self.txs {
            let _ = tx.send(Job::Shutdown);
        }
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

impl Drop for ExecutorPool {
    fn drop(&mut self) {
        for tx in &self.txs {
            let _ = tx.send(Job::Shutdown);
        }
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

/// Deterministic scoped fan-out for CPU-bound batch work (the parallel
/// fleet solver): apply `f` to every item across up to `threads` scoped
/// worker threads and return the results IN ITEM ORDER, regardless of
/// which worker computed what or when.  Work is strided — worker `w`
/// takes items `w, w+T, w+2T, …` — so the assignment is static and the
/// merge is an in-order join: callers get byte-identical results at any
/// thread count.  `threads <= 1` (or ≤ 1 item) runs inline on the
/// caller's thread with no spawn at all; that IS the sequential path,
/// not an approximation of it.
///
/// Unlike [`ExecutorPool`] this holds no long-lived threads: solver
/// ticks are bursty and rare (one per adaptation interval), so scoped
/// spawn-per-call beats keeping a fleet of idle workers warm, and the
/// borrow-friendly `std::thread::scope` lets `f` capture the solver's
/// per-member state by reference.
pub fn scoped_map<T, R, F>(threads: usize, items: &[T], f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(usize, &T) -> R + Sync,
{
    let n = items.len();
    if threads <= 1 || n <= 1 {
        return items.iter().enumerate().map(|(i, t)| f(i, t)).collect();
    }
    let workers = threads.min(n);
    let mut slots: Vec<Option<R>> = Vec::with_capacity(n);
    slots.resize_with(n, || None);
    std::thread::scope(|scope| {
        let f = &f;
        let handles: Vec<_> = (0..workers)
            .map(|w| {
                scope.spawn(move || {
                    let mut out = Vec::new();
                    let mut i = w;
                    while i < n {
                        out.push((i, f(i, &items[i])));
                        i += workers;
                    }
                    out
                })
            })
            .collect();
        for h in handles {
            for (i, r) in h.join().expect("solver worker panicked") {
                slots[i] = Some(r);
            }
        }
    });
    slots.into_iter().map(|r| r.expect("every index computed")).collect()
}

/// [`scoped_map`] over DISJOINT `&mut` items — the epoch-parallel DES
/// driver's fan-out, where each worker owns one member's whole state
/// bundle (core + wheel + lane) for the duration of the epoch.  Same
/// contract: strided static assignment (worker `w` takes items
/// `w, w+T, …`), results returned in item order, `threads <= 1` (or
/// ≤ 1 item) runs inline on the caller's thread — byte-identical
/// results at any thread count as long as `f(i, _)` touches only item
/// `i`'s state, which the `&mut` split enforces at compile time.
pub fn scoped_map_mut<T, R, F>(threads: usize, items: &mut [T], f: F) -> Vec<R>
where
    T: Send,
    R: Send,
    F: Fn(usize, &mut T) -> R + Sync,
{
    let n = items.len();
    if threads <= 1 || n <= 1 {
        return items.iter_mut().enumerate().map(|(i, t)| f(i, t)).collect();
    }
    let workers = threads.min(n);
    // split the slice into per-worker strided buckets of disjoint &mut
    let mut buckets: Vec<Vec<(usize, &mut T)>> = Vec::with_capacity(workers);
    buckets.resize_with(workers, Vec::new);
    for (i, t) in items.iter_mut().enumerate() {
        buckets[i % workers].push((i, t));
    }
    let mut slots: Vec<Option<R>> = Vec::with_capacity(n);
    slots.resize_with(n, || None);
    std::thread::scope(|scope| {
        let f = &f;
        let handles: Vec<_> = buckets
            .into_iter()
            .map(|bucket| {
                scope.spawn(move || {
                    bucket.into_iter().map(|(i, t)| (i, f(i, t))).collect::<Vec<_>>()
                })
            })
            .collect();
        for h in handles {
            for (i, r) in h.join().expect("epoch worker panicked") {
                slots[i] = Some(r);
            }
        }
    });
    slots.into_iter().map(|r| r.expect("every index computed")).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scoped_map_mut_mutates_in_place_and_merges_in_order() {
        for threads in [1usize, 2, 4, 16] {
            let mut items: Vec<u64> = (0..9).collect();
            let out = scoped_map_mut(threads, &mut items, |i, v| {
                *v += 100;
                (i as u64) * 2
            });
            assert_eq!(items, (100..109).collect::<Vec<u64>>(), "threads={threads}");
            assert_eq!(out, (0..9).map(|i| i * 2).collect::<Vec<u64>>(), "threads={threads}");
        }
    }

    #[test]
    fn scoped_map_matches_inline_at_any_thread_count() {
        let items: Vec<u32> = (0..13).collect();
        let expect: Vec<u32> = items.iter().map(|v| v * 3).collect();
        for threads in [1usize, 3, 8] {
            assert_eq!(scoped_map(threads, &items, |_, v| v * 3), expect);
        }
    }
}
