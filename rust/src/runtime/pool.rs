//! Executor pool: PJRT wrapper types are not `Send`, so each executor
//! thread owns its own [`Engine`] (its own PJRT client + compiled
//! executables) and work arrives over channels.  The live serving
//! engine's replicas submit batch executions here; the adapter submits
//! LSTM predictions.

use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

use crate::anyhow;
use crate::util::error::Result;

use super::engine::Engine;

/// A unit of work for an executor thread.
enum Job {
    ExecVariant {
        key: String,
        batch: usize,
        input: Vec<f32>,
        reply: Sender<Result<(Vec<f32>, Duration)>>,
    },
    Predict {
        window: Vec<f32>,
        reply: Sender<Result<f32>>,
    },
    Warm {
        key: String,
        batch: usize,
        reply: Sender<Result<()>>,
    },
    Shutdown,
}

/// Handle to a pool of executor threads, each owning one [`Engine`].
pub struct ExecutorPool {
    tx: Sender<Job>,
    rx_shared: Arc<Mutex<Receiver<Job>>>,
    handles: Vec<JoinHandle<()>>,
}

impl ExecutorPool {
    /// Spawn `n_threads` executors over `artifact_dir`.
    pub fn new(artifact_dir: &str, n_threads: usize) -> Result<ExecutorPool> {
        let (tx, rx) = channel::<Job>();
        let rx_shared = Arc::new(Mutex::new(rx));
        let mut handles = Vec::new();
        for i in 0..n_threads.max(1) {
            let rx = Arc::clone(&rx_shared);
            let dir = artifact_dir.to_string();
            handles.push(
                std::thread::Builder::new()
                    .name(format!("ipa-exec-{i}"))
                    .spawn(move || {
                        let mut engine = match Engine::new(&dir) {
                            Ok(e) => e,
                            Err(e) => {
                                crate::log_error!("pool", "engine init failed: {e:#}");
                                return;
                            }
                        };
                        loop {
                            let job = {
                                let guard = rx.lock().unwrap();
                                guard.recv()
                            };
                            match job {
                                Ok(Job::ExecVariant { key, batch, input, reply }) => {
                                    let r = engine.execute_variant(&key, batch, &input);
                                    let _ = reply.send(r);
                                }
                                Ok(Job::Predict { window, reply }) => {
                                    let _ = reply.send(engine.predict(&window));
                                }
                                Ok(Job::Warm { key, batch, reply }) => {
                                    let _ = reply.send(engine.load_variant(&key, batch));
                                }
                                Ok(Job::Shutdown) | Err(_) => return,
                            }
                        }
                    })
                    .expect("spawn executor"),
            );
        }
        Ok(ExecutorPool { tx, rx_shared, handles })
    }

    /// Synchronous batched forward pass on some executor.
    pub fn execute(&self, key: &str, batch: usize, input: Vec<f32>) -> Result<(Vec<f32>, Duration)> {
        let (reply, rx) = channel();
        self.tx
            .send(Job::ExecVariant { key: key.to_string(), batch, input, reply })
            .map_err(|_| anyhow!("pool closed"))?;
        rx.recv().map_err(|_| anyhow!("executor died"))?
    }

    /// Synchronous LSTM prediction.
    pub fn predict(&self, window: Vec<f32>) -> Result<f32> {
        let (reply, rx) = channel();
        self.tx.send(Job::Predict { window, reply }).map_err(|_| anyhow!("pool closed"))?;
        rx.recv().map_err(|_| anyhow!("executor died"))?
    }

    /// Pre-compile (key, batch) on one executor (first-touch warmup).
    pub fn warm(&self, key: &str, batch: usize) -> Result<()> {
        let (reply, rx) = channel();
        self.tx
            .send(Job::Warm { key: key.to_string(), batch, reply })
            .map_err(|_| anyhow!("pool closed"))?;
        rx.recv().map_err(|_| anyhow!("executor died"))?
    }

    /// A `Send` closure for [`crate::predictor::LstmPredictor`] that
    /// routes predictions through this pool.
    pub fn lstm_closure(self: &Arc<Self>) -> Box<dyn FnMut(&[f32]) -> f32 + Send> {
        let pool = Arc::clone(self);
        Box::new(move |window: &[f32]| match pool.predict(window.to_vec()) {
            Ok(v) => v,
            Err(e) => {
                crate::log_warn!("pool", "lstm predict failed: {e:#}");
                0.0
            }
        })
    }

    pub fn shutdown(mut self) {
        for _ in 0..self.handles.len() {
            let _ = self.tx.send(Job::Shutdown);
        }
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

impl Drop for ExecutorPool {
    fn drop(&mut self) {
        // Senders for all workers: closing tx ends recv loops.
        let _ = &self.rx_shared;
        for _ in 0..self.handles.len() {
            let _ = self.tx.send(Job::Shutdown);
        }
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}
