//! AOT artifact manifest — the contract between `python/compile/aot.py`
//! (which writes `artifacts/manifest.json`) and the Rust runtime.

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use crate::anyhow;
use crate::util::error::{Context, Result};
use crate::util::json::Json;

/// One lowered model-variant graph (a `variants/<key>_b<batch>.hlo.txt`).
#[derive(Debug, Clone)]
pub struct VariantArtifact {
    pub key: String,
    pub stage_type: String,
    pub variant: String,
    pub batch: usize,
    /// Path relative to the artifact root.
    pub path: String,
    pub hidden: usize,
    pub layers: usize,
    pub params_m: f64,
    pub base_alloc: u32,
    pub accuracy: f64,
    pub flops: u64,
    /// Reference output sum on the deterministic check input (batch 1),
    /// computed by the python oracle — verified by the runtime tests.
    pub check_sum_b1: f64,
}

/// The trained LSTM predictor artifact.
#[derive(Debug, Clone)]
pub struct PredictorArtifact {
    pub path: String,
    pub history: usize,
    pub horizon: usize,
    pub hidden: usize,
    pub scale: f64,
    /// Held-out SMAPE measured at training time (paper: 6.6%).
    pub test_smape_pct: f64,
    /// Reference prediction for window = linspace(5, 25, HISTORY).
    pub check_pred: f64,
}

/// Parsed manifest + artifact root.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub root: PathBuf,
    pub variants: Vec<VariantArtifact>,
    pub predictor: Option<PredictorArtifact>,
    index: HashMap<(String, usize), usize>,
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest> {
        let root = dir.as_ref().to_path_buf();
        let path = root.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {} (run `make artifacts`)", path.display()))?;
        Self::parse(&text, root)
    }

    /// Parse manifest JSON text (split out for tests).
    pub fn parse(text: &str, root: PathBuf) -> Result<Manifest> {
        let j = Json::parse(text).map_err(|e| anyhow!("manifest: {e}"))?;
        let arts = j
            .get("artifacts")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("manifest: missing artifacts[]"))?;
        let mut variants = Vec::new();
        let mut predictor = None;
        for a in arts {
            match a.get("kind").and_then(Json::as_str) {
                Some("variant") => variants.push(VariantArtifact {
                    key: field_str(a, "key")?,
                    stage_type: field_str(a, "stage_type")?,
                    variant: field_str(a, "variant")?,
                    batch: field_num(a, "batch")? as usize,
                    path: field_str(a, "path")?,
                    hidden: field_num(a, "hidden")? as usize,
                    layers: field_num(a, "layers")? as usize,
                    params_m: field_num(a, "params_m")?,
                    base_alloc: field_num(a, "base_alloc")? as u32,
                    accuracy: field_num(a, "accuracy")?,
                    flops: field_num(a, "flops")? as u64,
                    check_sum_b1: field_num(a, "check_sum_b1")?,
                }),
                Some("predictor") => {
                    predictor = Some(PredictorArtifact {
                        path: field_str(a, "path")?,
                        history: field_num(a, "history")? as usize,
                        horizon: field_num(a, "horizon")? as usize,
                        hidden: field_num(a, "hidden")? as usize,
                        scale: field_num(a, "scale")?,
                        test_smape_pct: a
                            .path(&["metrics", "test_smape_pct"])
                            .and_then(Json::as_f64)
                            .unwrap_or(f64::NAN),
                        check_pred: field_num(a, "check_pred")?,
                    })
                }
                _ => {}
            }
        }
        let mut index = HashMap::new();
        for (i, v) in variants.iter().enumerate() {
            index.insert((v.key.clone(), v.batch), i);
        }
        Ok(Manifest { root, variants, predictor, index })
    }

    /// Look up the artifact for (variant key, batch size).
    pub fn variant(&self, key: &str, batch: usize) -> Option<&VariantArtifact> {
        self.index.get(&(key.to_string(), batch)).map(|&i| &self.variants[i])
    }

    /// Absolute path of an artifact.
    pub fn abs_path(&self, rel: &str) -> PathBuf {
        self.root.join(rel)
    }

    /// Distinct variant keys present, sorted.
    pub fn keys(&self) -> Vec<String> {
        self.index
            .keys()
            .map(|(k, _)| k.clone())
            .collect::<std::collections::BTreeSet<_>>()
            .into_iter()
            .collect()
    }
}

fn field_str(j: &Json, k: &str) -> Result<String> {
    j.get(k)
        .and_then(Json::as_str)
        .map(str::to_string)
        .ok_or_else(|| anyhow!("manifest: missing string field {k}"))
}

fn field_num(j: &Json, k: &str) -> Result<f64> {
    j.get(k)
        .and_then(Json::as_f64)
        .ok_or_else(|| anyhow!("manifest: missing numeric field {k}"))
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "version": 1,
      "artifacts": [
        {"kind":"variant","key":"detect.yolov5n","stage_type":"detect",
         "variant":"yolov5n","batch":1,"path":"variants/detect.yolov5n_b1.hlo.txt",
         "hidden":32,"layers":3,"params_m":1.9,"base_alloc":1,"accuracy":45.7,
         "flops":6144,"check_sum_b1":1.25},
        {"kind":"predictor","path":"predictor/lstm.hlo.txt","history":120,
         "horizon":20,"hidden":32,"scale":50.0,
         "metrics":{"test_smape_pct":7.9},"check_pred":23.5}
      ]
    }"#;

    #[test]
    fn parse_sample() {
        let m = Manifest::parse(SAMPLE, PathBuf::from("/tmp/a")).unwrap();
        assert_eq!(m.variants.len(), 1);
        let v = m.variant("detect.yolov5n", 1).unwrap();
        assert_eq!(v.hidden, 32);
        assert_eq!(v.base_alloc, 1);
        assert!((v.check_sum_b1 - 1.25).abs() < 1e-12);
        let p = m.predictor.as_ref().unwrap();
        assert_eq!(p.history, 120);
        assert!((p.test_smape_pct - 7.9).abs() < 1e-12);
        assert!(m.variant("detect.yolov5n", 2).is_none());
        assert_eq!(m.keys(), vec!["detect.yolov5n".to_string()]);
    }

    #[test]
    fn rejects_missing_fields() {
        let bad = r#"{"artifacts":[{"kind":"variant","key":"x"}]}"#;
        assert!(Manifest::parse(bad, PathBuf::new()).is_err());
    }

    #[test]
    fn abs_path_joins_root() {
        let m = Manifest::parse(SAMPLE, PathBuf::from("/art")).unwrap();
        assert_eq!(
            m.abs_path("variants/x.hlo.txt"),
            PathBuf::from("/art/variants/x.hlo.txt")
        );
    }
}
