//! Latency profiles — the §4.2 representation of a model variant's
//! performance: latency as a quadratic in batch size, under its base
//! resource allocation.

use crate::models::registry::{StageType, Variant, BATCH_SIZES};
use crate::resources::{CostWeights, ResourceVec};

/// Quadratic latency model `l(b) = a·b² + β·b + γ` (seconds).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LatencyProfile {
    pub coef: [f64; 3],
}

impl LatencyProfile {
    pub fn new(coef: [f64; 3]) -> Self {
        Self { coef }
    }

    /// Batch-processing latency at batch size `b`, seconds.
    pub fn latency(&self, b: usize) -> f64 {
        let x = b as f64;
        (self.coef[0] * x * x + self.coef[1] * x + self.coef[2]).max(1e-9)
    }

    /// Per-replica throughput at batch size `b`, requests/second.
    pub fn throughput(&self, b: usize) -> f64 {
        b as f64 / self.latency(b)
    }

    /// The batch size (from the profiled set) maximizing throughput.
    pub fn best_batch(&self) -> usize {
        BATCH_SIZES
            .iter()
            .copied()
            .max_by(|&a, &b| self.throughput(a).partial_cmp(&self.throughput(b)).unwrap())
            .unwrap()
    }

    /// Uniformly time-scaled copy: `l'(b) = s · l(b)`.
    pub fn scaled(&self, s: f64) -> LatencyProfile {
        LatencyProfile::new([self.coef[0] * s, self.coef[1] * s, self.coef[2] * s])
    }
}

/// Profile of one variant in one pipeline stage: the latency model plus
/// the per-replica cost (base allocation) and accuracy.
#[derive(Debug, Clone)]
pub struct VariantProfile {
    pub variant: &'static Variant,
    pub latency: LatencyProfile,
}

impl VariantProfile {
    /// Per-replica resource demand (CPU cores, memory GB, accelerator
    /// slots) — what the fleet bin-packer places onto nodes.
    pub fn resources_per_replica(&self) -> ResourceVec {
        self.variant.resources()
    }

    /// Scalar cost of one replica: the default-weighted norm of the
    /// resource vector, which prices CPU cores only and therefore
    /// equals the paper's base allocation exactly (memory/accel bind
    /// through packing feasibility, not through the price).
    pub fn cost_per_replica(&self) -> f64 {
        self.resources_per_replica().weighted(CostWeights::default())
    }
}

/// All variant profiles for one pipeline stage.
#[derive(Debug, Clone)]
pub struct StageProfile {
    pub stage_type: StageType,
    pub variants: Vec<VariantProfile>,
}

impl StageProfile {
    /// §4.2 / Swayam rule: `SLA_s = 5 × avg(batch-1 latency)` across the
    /// stage's variants under base allocation.
    pub fn stage_sla(&self) -> f64 {
        let avg: f64 = self.variants.iter().map(|v| v.latency.latency(1)).sum::<f64>()
            / self.variants.len() as f64;
        5.0 * avg
    }
}

/// Complete profile set for one pipeline: one [`StageProfile`] per stage.
#[derive(Debug, Clone)]
pub struct PipelineProfiles {
    pub pipeline: String,
    pub stages: Vec<StageProfile>,
}

impl PipelineProfiles {
    /// `SLA_P = Σ SLA_s` (§4.2).
    pub fn sla_e2e(&self) -> f64 {
        self.stages.iter().map(|s| s.stage_sla()).sum()
    }

    /// Uniformly time-scaled copy of every variant's latency model —
    /// used to run paper-scale (seconds) profiles on a compressed wall
    /// clock (the λ/latency/SLA domain scales consistently, so solver
    /// decisions are preserved).
    pub fn scaled(&self, s: f64) -> PipelineProfiles {
        PipelineProfiles {
            pipeline: self.pipeline.clone(),
            stages: self
                .stages
                .iter()
                .map(|st| StageProfile {
                    stage_type: st.stage_type,
                    variants: st
                        .variants
                        .iter()
                        .map(|vp| VariantProfile {
                            variant: vp.variant,
                            latency: vp.latency.scaled(s),
                        })
                        .collect(),
                })
                .collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn latency_monotone_in_batch() {
        let p = LatencyProfile::new([0.004, 0.6, 0.35]);
        for w in BATCH_SIZES.windows(2) {
            assert!(p.latency(w[0]) < p.latency(w[1]));
        }
    }

    #[test]
    fn throughput_improves_with_batching() {
        // With a sub-linear latency curve, batching buys throughput.
        let p = LatencyProfile::new([0.0005, 0.01, 0.05]);
        assert!(p.throughput(8) > p.throughput(1));
        // optimum batch is b* = sqrt(γ/α) = 10 → nearest profiled is 8
        assert_eq!(p.best_batch(), 8);
    }

    #[test]
    fn throughput_batch_identity() {
        let p = LatencyProfile::new([0.001, 0.02, 0.08]);
        for &b in &BATCH_SIZES {
            let t = p.throughput(b);
            assert!((t * p.latency(b) - b as f64).abs() < 1e-9);
        }
    }

    #[test]
    fn latency_floor() {
        let p = LatencyProfile::new([0.0, 0.0, -5.0]);
        assert!(p.latency(1) > 0.0);
    }

    #[test]
    fn scalar_cost_is_the_default_weighted_norm() {
        // every registry variant: cost_per_replica == base allocation,
        // byte-for-byte what the pre-vector reports priced
        for v in &crate::models::registry::VARIANTS {
            let vp = VariantProfile { variant: v, latency: LatencyProfile::new([0.0, 0.0, 0.1]) };
            assert_eq!(vp.cost_per_replica(), v.base_alloc as f64, "{}", v.key());
            assert_eq!(vp.resources_per_replica(), v.resources());
        }
    }

    #[test]
    fn scaling_is_uniform() {
        let p = LatencyProfile::new([0.004, 0.6, 0.35]);
        let s = p.scaled(0.01);
        for &b in &BATCH_SIZES {
            assert!((s.latency(b) - 0.01 * p.latency(b)).abs() < 1e-12);
        }
        // throughput scales inversely; the optimal batch is unchanged
        assert_eq!(p.best_batch(), s.best_batch());
    }
}
