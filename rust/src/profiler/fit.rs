//! §4.2 profiling methodology: measure latency at power-of-two batch
//! sizes 1..64, fit a quadratic `l(b) = αb² + βb + γ`, and use the fit
//! to infer latencies at unmeasured batch sizes ("decreases the
//! profiling cost by an order of magnitude").

use super::profile::LatencyProfile;
use crate::util::stats;

/// Raw measurements: (batch, latency-seconds) pairs.
#[derive(Debug, Clone, Default)]
pub struct ProfileSamples {
    pub points: Vec<(usize, f64)>,
}

impl ProfileSamples {
    pub fn push(&mut self, batch: usize, latency_s: f64) {
        self.points.push((batch, latency_s));
    }

    /// Fit the quadratic latency model.  Returns `None` with <3 distinct
    /// batch sizes (the paper profiles 7).
    pub fn fit(&self) -> Option<LatencyProfile> {
        let xs: Vec<f64> = self.points.iter().map(|&(b, _)| b as f64).collect();
        let ys: Vec<f64> = self.points.iter().map(|&(_, l)| l).collect();
        let coef = stats::quadratic_fit(&xs, &ys)?;
        Some(LatencyProfile::new(coef))
    }

    /// MSE of the quadratic fit over the samples.
    pub fn quadratic_mse(&self) -> Option<f64> {
        let xs: Vec<f64> = self.points.iter().map(|&(b, _)| b as f64).collect();
        let ys: Vec<f64> = self.points.iter().map(|&(_, l)| l).collect();
        let coef = stats::quadratic_fit(&xs, &ys)?;
        Some(stats::fit_mse(&coef, &xs, &ys))
    }

    /// MSE of the *linear* fit (the paper compares and picks quadratic).
    pub fn linear_mse(&self) -> Option<f64> {
        let xs: Vec<f64> = self.points.iter().map(|&(b, _)| b as f64).collect();
        let ys: Vec<f64> = self.points.iter().map(|&(_, l)| l).collect();
        let c = stats::linear_fit(&xs, &ys)?;
        let errs: Vec<f64> = xs
            .iter()
            .zip(&ys)
            .map(|(&x, &y)| {
                let p = c[0] * x + c[1];
                (p - y) * (p - y)
            })
            .collect();
        Some(stats::mean(&errs))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::registry::BATCH_SIZES;

    fn curved_samples() -> ProfileSamples {
        let mut s = ProfileSamples::default();
        for &b in &BATCH_SIZES {
            let x = b as f64;
            s.push(b, 0.002 * x * x + 0.03 * x + 0.05);
        }
        s
    }

    #[test]
    fn fit_recovers_coefficients() {
        let p = curved_samples().fit().unwrap();
        assert!((p.coef[0] - 0.002).abs() < 1e-9);
        assert!((p.coef[1] - 0.03).abs() < 1e-7);
        assert!((p.coef[2] - 0.05).abs() < 1e-7);
    }

    #[test]
    fn quadratic_beats_linear() {
        // The §4.2 model-selection claim.
        let s = curved_samples();
        assert!(s.quadratic_mse().unwrap() < s.linear_mse().unwrap());
    }

    #[test]
    fn interpolates_unmeasured_batches() {
        let p = curved_samples().fit().unwrap();
        // batch 12 was never measured; the fit should land on the curve.
        let expected = 0.002 * 144.0 + 0.03 * 12.0 + 0.05;
        assert!((p.latency(12) - expected).abs() < 1e-6);
    }

    #[test]
    fn too_few_points() {
        let mut s = ProfileSamples::default();
        s.push(1, 0.1);
        s.push(2, 0.2);
        assert!(s.fit().is_none());
    }
}
