//! Eq. 1 base-allocation solver.
//!
//! §4.2: for each model variant, find the minimum per-container CPU
//! allocation `R_m` such that (1b) the variant sustains a threshold
//! throughput `th` and (1c) it can serve the largest batch size within
//! the per-stage SLA.  The allocation is then fixed at runtime; the
//! optimizer scales *horizontally* with that base allocation.

use super::analytic::{hw_latency, hw_throughput};
use crate::models::registry::Variant;

/// Candidate allocations, capped at 32 cores like Table 5.
pub const CORE_STEPS: [u32; 6] = [1, 2, 4, 8, 16, 32];

/// Result of Eq. 1 for one variant/threshold: `None` = infeasible within
/// the 32-core cap (the "×" entries in Table 5).
pub fn solve(v: &Variant, threshold_rps: f64, stage_sla: f64, max_batch: usize) -> Option<u32> {
    CORE_STEPS.iter().copied().find(|&c| {
        hw_throughput(v, 1, c) >= threshold_rps && hw_latency(v, max_batch, c) <= stage_sla
    })
}

/// Table 5 row: base allocations of every variant of a stage under a
/// given RPS threshold (None = ×).
pub fn table_row(
    variants: &[&'static Variant],
    threshold_rps: f64,
    stage_sla: f64,
    max_batch: usize,
) -> Vec<Option<u32>> {
    variants
        .iter()
        .map(|v| solve(v, threshold_rps, stage_sla, max_batch))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::registry::{variants_of, StageType};

    #[test]
    fn alloc_monotone_in_model_size() {
        // Table 5 shape: heavier variants need >= cores at equal threshold.
        let vs = variants_of(StageType::Detect);
        let allocs = table_row(&vs, 5.0, 4.62, 8);
        let got: Vec<u32> = allocs.iter().map(|a| a.unwrap_or(64)).collect();
        for w in got.windows(2) {
            assert!(w[0] <= w[1], "{got:?}");
        }
    }

    #[test]
    fn alloc_monotone_in_threshold() {
        // Higher RPS thresholds require >= cores (Table 5 columns).
        let vs = variants_of(StageType::Detect);
        let v = vs[2]; // yolov5m
        let a5 = solve(v, 5.0, 4.62, 8).unwrap_or(64);
        let a10 = solve(v, 10.0, 4.62, 8).unwrap_or(64);
        let a15 = solve(v, 15.0, 4.62, 8).unwrap_or(64);
        assert!(a5 <= a10 && a10 <= a15, "{a5} {a10} {a15}");
    }

    #[test]
    fn heavy_variant_at_high_threshold_infeasible() {
        // Table 5 has x entries: the cap binds for heavy models at high RPS.
        let vs = variants_of(StageType::Detect);
        let heavy = vs[4]; // yolov5x
        assert!(solve(heavy, 60.0, 1.0, 8).is_none());
    }

    #[test]
    fn light_variant_cheap() {
        let vs = variants_of(StageType::Detect);
        let light = vs[0]; // yolov5n: 80ms @1 core => 12.5 RPS >= 5
        assert_eq!(solve(light, 5.0, 4.62, 8), Some(1));
    }

    #[test]
    fn sla_constraint_binds() {
        // With a tight SLA for max batch, more cores are needed even at
        // a trivial throughput threshold (Eq. 1c).
        let vs = variants_of(StageType::Detect);
        let v = vs[2];
        let loose = solve(v, 0.1, 100.0, 64).unwrap();
        let tight = solve(v, 0.1, 2.0, 64).unwrap_or(64);
        assert!(tight >= loose, "{tight} vs {loose}");
    }
}
