//! Paper-scale analytic profiles.
//!
//! The paper profiles real DNNs on 96-core Chameleon nodes; we cannot
//! (repro gate), so simulator-mode experiments use analytic profiles
//! constructed to be *self-consistent with the paper's own numbers*:
//!
//! * Within a stage, the batch-1 latency of variant `m` under its base
//!   allocation is proportional to `params_m^0.75 / BA_m` — heavier
//!   models are slower, extra base cores buy sub-linear speedup.  This
//!   preserves the accuracy↔latency trade-off that drives every result.
//! * The per-(pipeline, stage) scale factor is calibrated so that
//!   `SLA_s = 5 × avg(batch-1 latency)` reproduces Table 6 exactly
//!   (the SLAs the e2e experiments run against).
//! * Batch scaling follows `g(b) = 0.35 + 0.6·b + 0.004·b²` (normalized
//!   to g(1)=1), matching the sub-linear per-item batching gains in
//!   Table 3 (e.g. ResNet18: 73 ms at b=1 → 383 ms at b=8 ≈ 5.2×).
//!
//! The same construction exposes a *hardware model* (1-core latencies +
//! a `cores^0.7` speedup law) for the Eq. 1 base-allocation solver and
//! the Table 2 / Table 5 reports.
//!
//! Live-engine runs use measured profiles of the real artifacts instead
//! (`profiler::measured` path via `runtime`).

use super::fit::ProfileSamples;
use super::profile::{LatencyProfile, PipelineProfiles, StageProfile, VariantProfile};
use crate::models::pipelines::PipelineSpec;
use crate::models::registry::{variants_of, StageType, Variant, BATCH_SIZES};

/// Batch-scaling shape `g(b)`, normalized so `g(1) = 1`.
pub fn batch_shape(b: usize) -> f64 {
    let x = b as f64;
    let g = 0.35 + 0.6 * x + 0.004 * x * x;
    let g1 = 0.35 + 0.6 + 0.004;
    g / g1
}

/// Relative weight of a variant under base allocation:
/// `params^0.75 / BA`.
pub fn variant_weight(v: &Variant) -> f64 {
    v.params_m.powf(0.75) / v.base_alloc as f64
}

/// Sub-linear multi-core speedup law (hardware model).
pub fn core_speedup(cores: u32) -> f64 {
    (cores as f64).powf(0.7)
}

/// 1-core batch-1 latency anchors per stage type, seconds — calibrated
/// to the paper's published single measurements (Table 2: ResNet18 at
/// 75 ms / 1 core; Table 3: YOLOv5n at 80 ms) and to plausible scales
/// for the NLP/audio stages.
pub fn stage_anchor_1core(t: StageType) -> f64 {
    match t {
        StageType::Detect => 0.080,    // yolov5n, Table 3
        StageType::Classify => 0.075,  // resnet18, Table 2
        StageType::Audio => 1.00,
        StageType::Qa => 0.15,
        StageType::Summarize => 0.40,
        StageType::Sentiment => 0.18,
        StageType::LangId => 0.19,
        StageType::Nmt => 0.50,
    }
}

/// Hardware model: latency of `v` at batch `b` on `cores` CPU cores.
/// Anchored so the *smallest* variant of the stage at 1 core / batch 1
/// hits [`stage_anchor_1core`].
pub fn hw_latency(v: &Variant, b: usize, cores: u32) -> f64 {
    let vs = variants_of(v.stage_type);
    let smallest = vs[0];
    let k = stage_anchor_1core(v.stage_type) / smallest.params_m.powf(0.75);
    k * v.params_m.powf(0.75) * batch_shape(b) / core_speedup(cores)
}

/// Hardware-model throughput (RPS) at batch `b` on `cores`.
pub fn hw_throughput(v: &Variant, b: usize, cores: u32) -> f64 {
    b as f64 / hw_latency(v, b, cores)
}

/// Build the paper-calibrated profiles for one pipeline.
///
/// Profiles are constructed by *sampling* the analytic curve at the
/// seven profiled batch sizes and running the §4.2 quadratic fit — the
/// same path measured profiles take — so the fit machinery is exercised
/// end-to-end.
pub fn pipeline_profiles(spec: &PipelineSpec) -> PipelineProfiles {
    let mut stages = Vec::new();
    for (si, &stage_type) in spec.stages.iter().enumerate() {
        let vs = variants_of(stage_type);
        // Calibrate k so 5 * mean(batch-1 latency) == Table 6 SLA_s.
        let mean_w: f64 = vs.iter().map(|v| variant_weight(v)).sum::<f64>() / vs.len() as f64;
        let target_mean_l1 = spec.stage_slas[si] / 5.0;
        let k = target_mean_l1 / mean_w;

        let mut variants = Vec::new();
        for v in vs {
            let l1 = k * variant_weight(v);
            let mut samples = ProfileSamples::default();
            for &b in &BATCH_SIZES {
                samples.push(b, l1 * batch_shape(b));
            }
            let latency: LatencyProfile = samples.fit().expect("7 batch points fit");
            variants.push(VariantProfile { variant: v, latency });
        }
        stages.push(StageProfile { stage_type, variants });
    }
    PipelineProfiles { pipeline: spec.name.to_string(), stages }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::pipelines;

    #[test]
    fn batch_shape_normalized() {
        assert!((batch_shape(1) - 1.0).abs() < 1e-12);
        assert!(batch_shape(8) > 4.0 && batch_shape(8) < 8.0, "sub-linear batching");
        assert!(batch_shape(64) < 64.0);
    }

    #[test]
    fn table6_slas_reproduced() {
        // The calibration target: stage SLAs computed from the profiles
        // must reproduce Table 6 to float precision.
        for spec in pipelines::all() {
            let prof = pipeline_profiles(&spec);
            for (si, st) in prof.stages.iter().enumerate() {
                let sla = st.stage_sla();
                assert!(
                    (sla - spec.stage_slas[si]).abs() < 1e-6,
                    "{} stage {si}: {sla} vs {}",
                    spec.name,
                    spec.stage_slas[si]
                );
            }
            assert!((prof.sla_e2e() - spec.sla_e2e()).abs() < 1e-6);
        }
    }

    #[test]
    fn heavier_variants_slower_within_stage() {
        let spec = pipelines::by_name("video").unwrap();
        let prof = pipeline_profiles(&spec);
        for st in &prof.stages {
            // params/BA weight ordering, not strictly params ordering.
            for pair in st.variants.windows(2) {
                let w0 = variant_weight(pair[0].variant);
                let w1 = variant_weight(pair[1].variant);
                let l0 = pair[0].latency.latency(1);
                let l1 = pair[1].latency.latency(1);
                assert_eq!(w0 < w1, l0 < l1, "latency follows weight ordering");
            }
        }
    }

    #[test]
    fn hw_model_anchors() {
        let v = crate::models::registry::by_key("classify.resnet18").unwrap();
        assert!((hw_latency(v, 1, 1) - 0.075).abs() < 1e-9);
        let y = crate::models::registry::by_key("detect.yolov5n").unwrap();
        assert!((hw_latency(y, 1, 1) - 0.080).abs() < 1e-9);
    }

    #[test]
    fn hw_model_table2_shape() {
        // Table 2 shape: more cores -> lower latency, higher throughput;
        // ResNet50 slower than ResNet18 at equal cores.
        let r18 = crate::models::registry::by_key("classify.resnet18").unwrap();
        let r50 = crate::models::registry::by_key("classify.resnet50").unwrap();
        for &c in &[1u32, 4, 8] {
            assert!(hw_latency(r50, 1, c) > hw_latency(r18, 1, c));
        }
        assert!(hw_latency(r18, 1, 8) < hw_latency(r18, 1, 4));
        assert!(hw_throughput(r18, 1, 8) > hw_throughput(r18, 1, 1));
    }

    #[test]
    fn speedup_sublinear() {
        assert!(core_speedup(4) < 4.0);
        assert!(core_speedup(4) > 2.0);
        assert!((core_speedup(1) - 1.0).abs() < 1e-12);
    }
}
