//! Deterministic replay driver: re-runs a recorded decision schedule
//! through the shared cluster core.
//!
//! This is the "third driver" the `cluster` refactor buys for free —
//! no adapter, no solver, no predictor: just the [`DecisionLog`] a
//! previous run captured, pushed through the exact same discrete-event
//! loop ([`run_des`]) and stage machinery.  With identical trace, seed
//! and noise settings, a replay reproduces the original run's
//! per-request outcomes bit-for-bit (the parity test pins this down),
//! which makes it the substrate for regression bisection and for
//! auditing production decision schedules offline.

use super::sim::{run_des, DecisionLog, DesController, SimConfig};
use crate::coordinator::adapter::Decision;
use crate::metrics::RunMetrics;
use crate::profiler::profile::PipelineProfiles;
use crate::workload::trace::Trace;

/// Re-run a recorded decision schedule.  `log` must come from
/// [`crate::simulator::sim::Simulation::run_logged`] (index 0 is the
/// initial decision); extra ticks beyond the log replay its last entry.
#[allow(clippy::too_many_arguments)]
pub fn replay(
    profiles: &PipelineProfiles,
    sla: f64,
    interval: f64,
    apply_delay: f64,
    sim: SimConfig,
    log: &DecisionLog,
    trace: &Trace,
    system: &str,
) -> RunMetrics {
    assert!(
        !log.decisions.is_empty(),
        "replay needs at least the initial decision (run_logged produces it)"
    );
    let mut ctl = ScriptedController { log, next: 0 };
    run_des(profiles, sla, interval, apply_delay, sim, &mut ctl, trace, system)
}

/// [`DesController`] that replays a recorded schedule verbatim.
struct ScriptedController<'a> {
    log: &'a DecisionLog,
    next: usize,
}

impl DesController for ScriptedController<'_> {
    fn initial(&mut self, _first_rate: f64) -> Decision {
        self.next = 1;
        self.log.decisions[0].clone()
    }

    fn decide(&mut self, _now: f64, _history: &[f64]) -> Decision {
        let i = self.next.min(self.log.decisions.len() - 1);
        self.next += 1;
        self.log.decisions[i].clone()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::adapter::{Adapter, AdapterConfig, Policy};
    use crate::models::accuracy::AccuracyMetric;
    use crate::models::pipelines;
    use crate::predictor::ReactivePredictor;
    use crate::profiler::analytic::pipeline_profiles;
    use crate::simulator::sim::Simulation;
    use crate::workload::tracegen::Pattern;

    #[test]
    fn replay_reproduces_adaptive_run_exactly() {
        let spec = pipelines::by_name("video").unwrap();
        let prof = pipeline_profiles(&spec);
        let sla = spec.sla_e2e();
        let cfg = AdapterConfig::default();
        let adapter = Adapter::new(
            spec,
            prof.clone(),
            Policy::Ipa(AccuracyMetric::Pas),
            cfg,
            Box::new(ReactivePredictor::default()),
        );
        let sim_cfg = SimConfig { seed: 13, ..Default::default() };
        let mut sim = Simulation::new(adapter, sim_cfg);
        let trace = Trace::synthetic(Pattern::Fluctuating, 150);
        let (original, log) = sim.run_logged(&trace);
        let replayed = replay(
            &prof,
            sla,
            cfg.interval,
            cfg.apply_delay,
            sim_cfg,
            &log,
            &trace,
            "replay",
        );
        assert_eq!(original.requests, replayed.requests);
        assert_eq!(original.intervals.len(), replayed.intervals.len());
    }
}
