//! The discrete-event cluster simulator — our Kubernetes substitute.
//!
//! Faithfully models the paper's serving stack (§3): per-stage central
//! queues with batch formation, round-robin dispatch to replicas,
//! request dropping (§4.5), the adapter loop at a fixed monitoring
//! interval, and a reconfiguration delay before new configurations take
//! effect (§5.3's ~8 s adaptation process).
//!
//! Service times come from the latency profiles (optionally with
//! multiplicative noise); replicas are capacity slots — when a
//! reconfiguration shrinks a stage, in-flight batches finish at the old
//! latency while new batches use the new profile (rolling update
//! semantics).

use super::events::{Event, EventQueue};
use crate::coordinator::adapter::{Adapter, Decision};
use crate::coordinator::monitoring::Monitor;
use crate::metrics::{IntervalRecord, RequestRecord, RunMetrics};
use crate::optimizer::ip::PipelineConfig;
use crate::queueing::{worst_case_delay, CentralQueue, Request};
use crate::util::rng::SplitMix64;
use crate::workload::trace::Trace;

/// Simulation settings.
#[derive(Debug, Clone, Copy)]
pub struct SimConfig {
    /// Multiplicative service-time noise stddev (0 = deterministic).
    pub service_noise: f64,
    /// Arrival sampling seed.
    pub seed: u64,
    /// §4.5: drop at stage entry if age > SLA (for stages after the
    /// first), and anywhere if age > 2×SLA.
    pub drop_enabled: bool,
}

impl Default for SimConfig {
    fn default() -> Self {
        SimConfig { service_noise: 0.03, seed: 7, drop_enabled: true }
    }
}

struct StageState {
    queue: CentralQueue,
    /// Active variant index into the profiles.
    variant_idx: usize,
    batch: usize,
    replicas: u32,
    busy: u32,
}

/// One simulated request in flight.
#[derive(Debug, Clone, Copy)]
struct Flight {
    arrival: f64,
    completion: Option<f64>,
    dropped: bool,
}

/// The simulator.
pub struct Simulation {
    pub adapter: Adapter,
    pub sim: SimConfig,
}

impl Simulation {
    pub fn new(adapter: Adapter, sim: SimConfig) -> Self {
        Simulation { adapter, sim }
    }

    /// Run the full trace; returns the collected metrics.
    pub fn run(&mut self, trace: &Trace) -> RunMetrics {
        let n_stages = self.adapter.profiles.stages.len();
        let sla = self.adapter.spec.sla_e2e();
        let interval = self.adapter.config.interval;
        let apply_delay = self.adapter.config.apply_delay;
        let horizon = trace.seconds() as f64;

        let mut rng = SplitMix64::new(self.sim.seed ^ 0x51A7_E);
        let mut events = EventQueue::new();
        let mut monitor = Monitor::new(600);

        // Request table.
        let arrivals = trace.arrivals(self.sim.seed);
        let mut flights: Vec<Flight> = arrivals
            .iter()
            .map(|&t| Flight { arrival: t, completion: None, dropped: false })
            .collect();
        for (id, &t) in arrivals.iter().enumerate() {
            events.push(t, Event::Arrival { id: id as u64 });
        }

        // Initial configuration: decide on the trace's first-second rate.
        let first_rate = trace.rate_at(0.0);
        let init = self.adapter.decide_for_lambda(first_rate);
        let mut stages: Vec<StageState> = (0..n_stages)
            .map(|si| {
                let sc = &init.config.stages[si];
                StageState {
                    queue: CentralQueue::new(
                        sc.batch,
                        batch_timeout(sc.batch, init.lambda_predicted),
                    ),
                    variant_idx: sc.variant_idx,
                    batch: sc.batch,
                    replicas: sc.replicas,
                    busy: 0,
                }
            })
            .collect();
        let mut active_cfg: PipelineConfig = init.config.clone();
        let mut decisions: Vec<Decision> = vec![init];
        let mut intervals: Vec<IntervalRecord> = Vec::new();

        events.push(interval, Event::Adapt);
        events.push(horizon, Event::End);

        // Stage request sub-queues carry (Request) through; flights index
        // by id for final bookkeeping.
        while let Some((now, ev)) = events.pop() {
            match ev {
                Event::End => break,
                Event::Arrival { id } => {
                    monitor.record_arrival(now);
                    let req = Request { id, arrival: now, stage_arrival: now };
                    stages[0].queue.push(req);
                    self.dispatch(0, now, &mut stages, &mut events, &mut flights, sla, &mut rng);
                }
                Event::QueueCheck { stage } => {
                    self.dispatch(stage, now, &mut stages, &mut events, &mut flights, sla, &mut rng);
                }
                Event::ServiceDone { stage, ids, started: _ } => {
                    stages[stage].busy = stages[stage].busy.saturating_sub(1);
                    if stage + 1 < n_stages {
                        for id in ids {
                            let f = &flights[id as usize];
                            if f.dropped {
                                continue;
                            }
                            stages[stage + 1].queue.push(Request {
                                id,
                                arrival: f.arrival,
                                stage_arrival: now,
                            });
                        }
                        self.dispatch(
                            stage + 1, now, &mut stages, &mut events, &mut flights, sla, &mut rng,
                        );
                    } else {
                        for id in ids {
                            let f = &mut flights[id as usize];
                            if !f.dropped {
                                f.completion = Some(now);
                            }
                        }
                    }
                    // freed replica may unblock this stage's queue
                    self.dispatch(stage, now, &mut stages, &mut events, &mut flights, sla, &mut rng);
                }
                Event::Adapt => {
                    let history = monitor.history(now, crate::predictor::HISTORY);
                    let decision = self.adapter.decide(now, &history);
                    let observed = monitor.recent_rate(now, interval as usize);
                    intervals.push(IntervalRecord {
                        t: now,
                        pas: active_cfg.pas,
                        cost: active_cfg.cost,
                        lambda_observed: observed,
                        lambda_predicted: decision.lambda_predicted,
                        decision_time: decision.decision_time,
                        variants: active_cfg
                            .stages
                            .iter()
                            .map(|s| s.variant_key.clone())
                            .collect(),
                    });
                    decisions.push(decision);
                    events.push(now + apply_delay, Event::ApplyConfig {
                        decision_idx: decisions.len() - 1,
                    });
                    if now + interval < horizon {
                        events.push(now + interval, Event::Adapt);
                    }
                }
                Event::ApplyConfig { decision_idx } => {
                    let d = &decisions[decision_idx];
                    active_cfg = d.config.clone();
                    for (si, sc) in d.config.stages.iter().enumerate() {
                        let st = &mut stages[si];
                        st.variant_idx = sc.variant_idx;
                        st.batch = sc.batch;
                        st.replicas = sc.replicas;
                        st.queue
                            .set_batch(sc.batch, batch_timeout(sc.batch, d.lambda_predicted));
                        self.dispatch(si, now, &mut stages, &mut events, &mut flights, sla, &mut rng);
                    }
                }
            }
        }

        // Whatever is still queued/in-flight at the end never completed.
        let requests: Vec<RequestRecord> = flights
            .iter()
            .enumerate()
            .map(|(id, f)| RequestRecord {
                id: id as u64,
                arrival: f.arrival,
                completion: if f.dropped { None } else { f.completion },
            })
            .collect();

        RunMetrics {
            system: self.adapter.policy.name().to_string(),
            pipeline: self.adapter.spec.name.to_string(),
            workload: trace.name.clone(),
            requests,
            intervals,
            sla,
        }
    }

    /// Try to start service on `stage` while batches and replicas allow;
    /// applies the §4.5 drop policy when forming batches.
    #[allow(clippy::too_many_arguments)]
    fn dispatch(
        &mut self,
        stage: usize,
        now: f64,
        stages: &mut [StageState],
        events: &mut EventQueue,
        flights: &mut [Flight],
        sla: f64,
        rng: &mut SplitMix64,
    ) {
        loop {
            let st = &mut stages[stage];
            if st.busy >= st.replicas {
                return;
            }
            let Some(batch) = st.queue.pop_batch(now) else {
                // nothing ready: if a partial batch is pending, schedule
                // its timeout wakeup
                if let Some(at) = st.queue.next_timeout_at() {
                    if at > now {
                        events.push(at, Event::QueueCheck { stage });
                    }
                }
                return;
            };
            // §4.5 dropping at batch formation.
            let mut ids = Vec::with_capacity(batch.len());
            for req in batch {
                let age = now - req.arrival;
                let drop = self.sim.drop_enabled
                    && ((stage > 0 && age > sla) || age > 2.0 * sla);
                if drop {
                    flights[req.id as usize].dropped = true;
                } else {
                    ids.push(req.id);
                }
            }
            if ids.is_empty() {
                continue; // batch fully dropped; try to form another
            }
            let vp = &self.adapter.profiles.stages[stage].variants[st.variant_idx];
            let mut service = vp.latency.latency(st.batch);
            if self.sim.service_noise > 0.0 {
                let f = 1.0 + self.sim.service_noise * rng.next_normal();
                service *= f.clamp(0.5, 2.0);
            }
            st.busy += 1;
            events.push(now + service, Event::ServiceDone { stage, ids, started: now });
        }
    }
}

/// Batch-formation timeout: 1.5× the Eq. 7 worst-case wait, floored to
/// 50 ms — partial batches keep latency bounded under thin load.
fn batch_timeout(batch: usize, lambda: f64) -> f64 {
    (1.5 * worst_case_delay(batch, lambda)).max(0.05)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::adapter::{Adapter, AdapterConfig, Policy};
    use crate::models::accuracy::AccuracyMetric;
    use crate::models::pipelines;
    use crate::predictor::ReactivePredictor;
    use crate::profiler::analytic::pipeline_profiles;
    use crate::workload::tracegen::Pattern;

    fn make_sim(pipeline: &str, policy: Policy) -> Simulation {
        let spec = pipelines::by_name(pipeline).unwrap();
        let prof = pipeline_profiles(&spec);
        let adapter = Adapter::new(
            spec,
            prof,
            policy,
            AdapterConfig::default(),
            Box::new(ReactivePredictor::default()),
        );
        Simulation::new(adapter, SimConfig { seed: 3, ..Default::default() })
    }

    #[test]
    fn video_steady_low_mostly_within_sla() {
        let mut sim = make_sim("video", Policy::Ipa(AccuracyMetric::Pas));
        let trace = Trace::synthetic(Pattern::SteadyLow, 240);
        let m = sim.run(&trace);
        assert!(m.requests.len() > 800, "{}", m.requests.len());
        let att = m.sla_attainment();
        assert!(att > 0.85, "attainment {att}");
        assert!(m.drop_rate() < 0.1, "drops {}", m.drop_rate());
        assert!(!m.intervals.is_empty());
    }

    #[test]
    fn deterministic_given_seed() {
        let t = Trace::synthetic(Pattern::SteadyLow, 120);
        let m1 = make_sim("video", Policy::Fa2Low).run(&t);
        let m2 = make_sim("video", Policy::Fa2Low).run(&t);
        assert_eq!(m1.requests.len(), m2.requests.len());
        assert_eq!(m1.latencies(), m2.latencies());
    }

    #[test]
    fn fa2_low_cheaper_than_fa2_high() {
        let t = Trace::synthetic(Pattern::SteadyLow, 180);
        let low = make_sim("video", Policy::Fa2Low).run(&t);
        let high = make_sim("video", Policy::Fa2High).run(&t);
        assert!(low.avg_cost() < high.avg_cost());
        assert!(low.avg_pas() < high.avg_pas());
    }

    #[test]
    fn ipa_between_fa2_bounds_on_pas() {
        // §5.2: FA2-low/high provide the PAS floor/ceiling.
        let t = Trace::synthetic(Pattern::Fluctuating, 240);
        let low = make_sim("video", Policy::Fa2Low).run(&t);
        let high = make_sim("video", Policy::Fa2High).run(&t);
        let ipa = make_sim("video", Policy::Ipa(AccuracyMetric::Pas)).run(&t);
        assert!(ipa.avg_pas() >= low.avg_pas() - 1e-9, "{} vs {}", ipa.avg_pas(), low.avg_pas());
        assert!(ipa.avg_pas() <= high.avg_pas() + 1e-9);
    }

    #[test]
    fn completions_never_precede_arrivals() {
        let t = Trace::synthetic(Pattern::Bursty, 150);
        let m = make_sim("video", Policy::Ipa(AccuracyMetric::Pas)).run(&t);
        for r in &m.requests {
            if let Some(c) = r.completion {
                assert!(c >= r.arrival);
            }
        }
    }

    #[test]
    fn drops_bounded_by_2sla_rule() {
        // With dropping enabled, completed latencies stay under ~2×SLA
        // plus one service time.
        let t = Trace::synthetic(Pattern::Bursty, 200);
        let mut sim = make_sim("video", Policy::Fa2Low);
        sim.sim.drop_enabled = true;
        let m = sim.run(&t);
        let max_lat = m.latencies().iter().fold(0.0f64, |a, &b| a.max(b));
        assert!(max_lat < 3.0 * m.sla, "max latency {max_lat} vs sla {}", m.sla);
    }

    #[test]
    fn three_stage_pipeline_runs() {
        let t = Trace::synthetic(Pattern::SteadyLow, 120);
        let m = make_sim("nlp", Policy::Ipa(AccuracyMetric::Pas)).run(&t);
        assert!(m.sla_attainment() > 0.5, "{}", m.sla_attainment());
    }
}
