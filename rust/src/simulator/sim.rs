//! The discrete-event driver — our Kubernetes substitute, layered on
//! the shared [`crate::cluster`] core.
//!
//! This file owns only the *clock*: a deterministic event queue feeding
//! virtual timestamps into [`ClusterCore`].  Batch formation, §4.5
//! dropping, rolling reconfiguration and request/interval accounting
//! all live in `cluster::` and are byte-for-byte the same machinery the
//! live serving engine runs on a wall clock (`serving::engine`) and the
//! replay driver re-runs from a decision log (`simulator::replay`).
//!
//! Service times come from the latency profiles (optionally with
//! multiplicative noise); replicas are capacity slots — when a
//! reconfiguration shrinks a stage, in-flight batches finish at the old
//! latency while new batches use the new profile (rolling update
//! semantics, §5.3).
//!
//! [`run_des`] is generic over a [`DesController`] (the decision
//! source): [`Simulation`] plugs in the live [`Adapter`], while
//! `simulator::replay` scripts a recorded [`Decision`] log through the
//! identical loop.
//!
//! [`run_fleet`] is the same loop fanned out over a whole fleet:
//! every member pipeline's events interleave on one deterministic
//! virtual clock — SHARDED by default into per-member event wheels
//! merged by a `next_due` tournament
//! ([`crate::data_plane::wheel::ShardedClock`]; pop order is
//! byte-for-byte the single-heap order, [`SimConfig::legacy_clock`]
//! routes through one heap as the A/B lever) — a [`FleetController`]
//! (usually [`crate::fleet::solver::FleetAdapter`]) makes one *joint*
//! decision per tick, and the budget-checked [`FleetCore`] applies it
//! atomically.  The elastic hooks ride the same queue: each Adapt tick
//! first offers the controller a pool resize (growth immediate, shrink
//! staged with the decisions), and a mid-interval Preempt event lets a
//! tuned controller move replicas to a bursting high-priority member
//! without waiting for the next tick — both no-ops for plain
//! controllers, so the classic fixed-pool behavior is unchanged.
//!
//! # Epoch-parallel fleet driver
//!
//! Members interact ONLY through the global control events
//! (Adapt/Preempt/Apply/Fault/End), which ride the clock's dedicated
//! global wheel — between two consecutive global events every member's
//! events (arrivals, batch forms, completions) touch only that
//! member's [`ClusterCore`], monitor, RNG stream and span buffer.  The
//! default driver exploits that: each step reads the global wheel's
//! `next_due` as the barrier, fans the members across
//! [`crate::runtime::pool::scoped_map_mut`] worker threads (disjoint
//! `&mut` per member), drains each member's wheel strictly up to the
//! barrier ([`crate::data_plane::wheel::EventWheel::pop_until`]), then
//! executes the global event sequentially and repeats.  Determinism
//! contract, pinned by `rust/tests/sim_parallel.rs`: per-member event
//! order, per-request outcomes, the control-plane journal, spans and
//! merged fleet metrics are byte-identical at ANY thread count —
//! in-epoch pushes are stamped from per-member sequence sub-ranges
//! (no shared counter mid-epoch), spans and pool-contribution changes
//! buffer per member and fold at barriers in fixed member order, and
//! every member draws service noise from its own seeded RNG stream.
//! `IPA_SIM_THREADS` / [`set_sim_threads`] /
//! [`SimConfig::sim_threads`] pick the worker count;
//! [`SimConfig::sequential_epochs`] is the one-worker A/B lever and
//! [`SimConfig::legacy_clock`] bypasses the epoch driver entirely for
//! the original one-event-at-a-time pop loop.

use super::events::{Event, EventQueue};
use crate::cluster::core::{ClusterCore, FormOutcome};
use crate::cluster::drop_policy::DropPolicy;
use crate::cluster::reconfig::Reconfig;
use crate::coordinator::adapter::{Adapter, Decision};
use crate::coordinator::monitoring::Monitor;
use crate::data_plane::wheel::{EventWheel, ShardedClock, EPOCH_SEQ_STRIDE};
use crate::fleet::core::{FleetCore, FleetReconfig, MemberInit, PoolReport};
use crate::fleet::router::{RouteOutcome, Router, RouterConfig};
use crate::fleet::solver::FleetController;
use crate::metrics::{RouterStats, RunMetrics};
use crate::optimizer::ip::PipelineConfig;
use crate::profiler::profile::PipelineProfiles;
use crate::runtime::pool::scoped_map_mut;
use crate::telemetry::hist::Histogram;
use crate::telemetry::{journal, Hop, Span, Telemetry};
use crate::util::json::Json;
use crate::util::rng::SplitMix64;
use crate::workload::trace::Trace;
use crate::workload::tracegen::member_seed;

/// Simulation settings.
#[derive(Debug, Clone, Copy)]
pub struct SimConfig {
    /// Multiplicative service-time noise stddev (0 = deterministic).
    pub service_noise: f64,
    /// Arrival sampling seed.
    pub seed: u64,
    /// §4.5: drop at stage entry if age > SLA (for stages after the
    /// first), and anywhere if age > 2×SLA.
    pub drop_enabled: bool,
    /// Route the fleet DES through the legacy single-heap clock instead
    /// of the sharded per-member wheels
    /// ([`crate::data_plane::wheel::ShardedClock`]).  Pop order — and
    /// therefore every metric — is identical either way; this is the
    /// A/B lever for the `data_plane` bench section.  Also forces the
    /// fleet loop fully sequential (the epoch-parallel driver requires
    /// per-member wheels).
    pub legacy_clock: bool,
    /// Run the epoch-parallel fleet driver single-threaded (one worker
    /// advancing every member in order).  The A/B lever for the
    /// `sim_parallel` bench section; results are byte-identical either
    /// way — that is the determinism contract under test.
    pub sequential_epochs: bool,
    /// Worker threads for the epoch-parallel fleet driver.  `0` (the
    /// default) defers to [`set_sim_threads`] / `IPA_SIM_THREADS` /
    /// available cores; tests pin explicit counts here so concurrently
    /// running tests never race on the process-wide knob.
    pub sim_threads: usize,
}

impl Default for SimConfig {
    fn default() -> Self {
        SimConfig {
            service_noise: 0.03,
            seed: 7,
            drop_enabled: true,
            legacy_clock: false,
            sequential_epochs: false,
            sim_threads: 0,
        }
    }
}

/// Process-wide override for the epoch-parallel DES worker count
/// (0 = not set).  Same pattern as
/// [`crate::fleet::solver::set_solver_threads`].
static SIM_THREADS: std::sync::atomic::AtomicUsize = std::sync::atomic::AtomicUsize::new(0);

/// `IPA_SIM_THREADS`, parsed once (0 = unset/invalid).
fn env_sim_threads() -> usize {
    static ENV: std::sync::OnceLock<usize> = std::sync::OnceLock::new();
    *ENV.get_or_init(|| {
        std::env::var("IPA_SIM_THREADS")
            .ok()
            .and_then(|v| v.trim().parse::<usize>().ok())
            .filter(|&n| n >= 1)
            .unwrap_or(0)
    })
}

/// Worker threads the epoch-parallel fleet DES fans members across:
/// the [`set_sim_threads`] override if set, else `IPA_SIM_THREADS`,
/// else available cores capped at 8 (fleet epochs are short — beyond
/// a handful of workers the spawn/join overhead dominates).  `1` is
/// the sequential path.  Thread count may only change HOW the epoch
/// is computed, never WHAT it computes — runs are byte-identical at
/// any value.
pub fn sim_threads() -> usize {
    let o = SIM_THREADS.load(std::sync::atomic::Ordering::Relaxed);
    if o != 0 {
        return o;
    }
    let e = env_sim_threads();
    if e != 0 {
        return e;
    }
    std::thread::available_parallelism().map_or(1, |n| n.get().min(8))
}

/// Override the DES worker count for this process (0 clears the
/// override; benches A/B via this without touching the environment).
pub fn set_sim_threads(n: usize) {
    SIM_THREADS.store(n, std::sync::atomic::Ordering::Relaxed);
}

/// A decision source for the discrete-event driver.
pub trait DesController {
    /// The initial configuration, decided on the trace's first-second
    /// rate before any request arrives.
    fn initial(&mut self, first_rate: f64) -> Decision;

    /// One adaptation-tick decision from the observed load history.
    fn decide(&mut self, now: f64, history: &[f64]) -> Decision;
}

/// Every decision an adaptive run made, in order: index 0 is the
/// initial configuration, then one entry per adaptation tick.  Feed it
/// to [`crate::simulator::replay::replay`] to re-run the schedule
/// deterministically.
#[derive(Debug, Clone, Default)]
pub struct DecisionLog {
    pub decisions: Vec<Decision>,
}

/// Options for one [`Simulation`] run.  `run`/`run_logged`/`run_traced`
/// are thin views over [`Simulation::run_with`] — this struct is where
/// new knobs land without growing another entry-point name.
#[derive(Default)]
pub struct RunOptions<'a> {
    /// Flight recorder; `None` runs untraced (identical schedule).
    pub telemetry: Option<&'a Telemetry>,
}

/// The adapter-driven simulator.
pub struct Simulation {
    pub adapter: Adapter,
    pub sim: SimConfig,
}

impl Simulation {
    pub fn new(adapter: Adapter, sim: SimConfig) -> Self {
        Simulation { adapter, sim }
    }

    /// Run the full trace; returns the collected metrics.
    pub fn run(&mut self, trace: &Trace) -> RunMetrics {
        self.run_with(trace, RunOptions::default()).0
    }

    /// Run the full trace, also capturing the decision schedule for
    /// deterministic replay.
    pub fn run_logged(&mut self, trace: &Trace) -> (RunMetrics, DecisionLog) {
        self.run_with(trace, RunOptions::default())
    }

    /// [`Simulation::run_logged`] with the flight recorder attached:
    /// sampled requests emit spans and every decision lands in the
    /// journal as a replayable `decision` entry.
    pub fn run_traced(&mut self, trace: &Trace, tel: &Telemetry) -> (RunMetrics, DecisionLog) {
        self.run_with(trace, RunOptions { telemetry: Some(tel) })
    }

    /// The single run entry point the named variants delegate to.
    pub fn run_with(&mut self, trace: &Trace, opts: RunOptions<'_>) -> (RunMetrics, DecisionLog) {
        let profiles = self.adapter.profiles.clone();
        let sla = self.adapter.spec.sla_e2e();
        let interval = self.adapter.config.interval;
        let apply_delay = self.adapter.config.apply_delay;
        let system = self.adapter.policy.name().to_string();
        let sim = self.sim;
        let mut ctl = AdapterController { adapter: &mut self.adapter, log: Vec::new() };
        let metrics = run_des_with(
            DesParams {
                profiles: &profiles,
                sla,
                interval,
                apply_delay,
                sim,
                system: &system,
                telemetry: opts.telemetry,
            },
            &mut ctl,
            trace,
        );
        (metrics, DecisionLog { decisions: ctl.log })
    }
}

/// [`DesController`] over the live [`Adapter`], recording every
/// decision for replay.
struct AdapterController<'a> {
    adapter: &'a mut Adapter,
    log: Vec<Decision>,
}

impl DesController for AdapterController<'_> {
    fn initial(&mut self, first_rate: f64) -> Decision {
        let d = self.adapter.decide_for_lambda(first_rate);
        self.log.push(d.clone());
        d
    }

    fn decide(&mut self, now: f64, history: &[f64]) -> Decision {
        let d = self.adapter.decide(now, history);
        self.log.push(d.clone());
        d
    }
}

/// Options for one single-pipeline DES run — the one entry point
/// ([`run_des_with`]) behind the historical [`run_des`]/
/// [`run_des_traced`] names.
pub struct DesParams<'a> {
    pub profiles: &'a PipelineProfiles,
    pub sla: f64,
    pub interval: f64,
    pub apply_delay: f64,
    pub sim: SimConfig,
    pub system: &'a str,
    /// Flight recorder; `None` runs untraced (identical schedule).
    pub telemetry: Option<&'a Telemetry>,
}

/// The discrete-event loop over the shared cluster core.
///
/// Deterministic given (`trace`, `sim.seed`, controller decisions):
/// arrivals, batch formation, drops, service times and reconfiguration
/// instants all derive from those inputs alone.
#[allow(clippy::too_many_arguments)]
pub fn run_des(
    profiles: &PipelineProfiles,
    sla: f64,
    interval: f64,
    apply_delay: f64,
    sim: SimConfig,
    ctl: &mut dyn DesController,
    trace: &Trace,
    system: &str,
) -> RunMetrics {
    run_des_with(
        DesParams { profiles, sla, interval, apply_delay, sim, system, telemetry: None },
        ctl,
        trace,
    )
}

/// Journal one adaptation decision (replayable via
/// [`journal::decisions_from_journal`]).  `decision_time` is
/// deliberately NOT journaled: it is a wall-clock reading and would
/// break byte-for-byte journal reproducibility.
fn journal_decision(tel: &Telemetry, now: f64, member: u32, d: &Decision) {
    tel.journal().record(
        now,
        "decision",
        Json::obj()
            .set("member", member as i64)
            .set("lambda_predicted", d.lambda_predicted)
            .set("fallback", d.fallback)
            .set("config", journal::config_to_json(&d.config)),
    );
}

/// [`run_des`] with the flight recorder attached.  Tracing is purely
/// observational: the event schedule, RNG draws and metrics are
/// byte-for-byte identical with telemetry on or off.
#[allow(clippy::too_many_arguments)]
pub fn run_des_traced(
    profiles: &PipelineProfiles,
    sla: f64,
    interval: f64,
    apply_delay: f64,
    sim: SimConfig,
    ctl: &mut dyn DesController,
    trace: &Trace,
    system: &str,
    tel: &Telemetry,
) -> RunMetrics {
    run_des_with(
        DesParams { profiles, sla, interval, apply_delay, sim, system, telemetry: Some(tel) },
        ctl,
        trace,
    )
}

/// The single-pipeline DES entry point the named variants delegate to.
pub fn run_des_with(
    p: DesParams<'_>,
    ctl: &mut dyn DesController,
    trace: &Trace,
) -> RunMetrics {
    let DesParams { profiles, sla, interval, apply_delay, sim, system, telemetry } = p;
    let off = Telemetry::off();
    let tel = telemetry.unwrap_or(&off);
    let horizon = trace.seconds() as f64;
    let mut rng = SplitMix64::new(sim.seed ^ 0x51A7_E);
    let mut events = EventQueue::new();
    let mut monitor = Monitor::new(600);

    let arrivals = trace.arrivals(sim.seed);
    for (id, &t) in arrivals.iter().enumerate() {
        events.push(t, Event::Arrival { id: id as u64 });
    }

    // Initial configuration: decide on the trace's first-second rate.
    let init = ctl.initial(trace.rate_at(0.0));
    journal_decision(tel, 0.0, 0, &init);
    let mut core = ClusterCore::new(
        &init.config,
        init.lambda_predicted,
        DropPolicy::new(sla, sim.drop_enabled),
    );
    let mut reconfig = Reconfig::new(apply_delay);
    let mut active_cfg = init.config.clone();
    let n_stages = core.n_stages();

    events.push(interval, Event::Adapt);
    events.push(horizon, Event::End);

    while let Some((now, ev)) = events.pop() {
        match ev {
            Event::End => break,
            Event::Arrival { id } => {
                monitor.record_arrival(now);
                if tel.enabled() && tel.sampled(id) {
                    tel.record(Span {
                        trace: id,
                        member: 0,
                        stage: 0,
                        hop: Hop::Arrival,
                        t: now,
                        dur: 0.0,
                        value: 0.0,
                    });
                }
                core.ingest(id, now);
                drive(
                    &mut core,
                    profiles,
                    0,
                    now,
                    &mut rng,
                    sim.service_noise,
                    tel,
                    0,
                    None,
                    &mut |s| tel.record(s),
                    &mut |t, e| events.push(t, e),
                );
            }
            Event::QueueCheck { stage } => {
                drive(
                    &mut core,
                    profiles,
                    stage,
                    now,
                    &mut rng,
                    sim.service_noise,
                    tel,
                    0,
                    None,
                    &mut |s| tel.record(s),
                    &mut |t, e| events.push(t, e),
                );
            }
            Event::ServiceDone { stage, batch } => {
                core.finish_service(stage);
                if stage + 1 < n_stages {
                    for req in batch {
                        if core.accounting.is_dropped(req.id) {
                            if tel.enabled() && tel.sampled(req.id) {
                                tel.record(Span {
                                    trace: req.id,
                                    member: 0,
                                    stage: stage as u32,
                                    hop: Hop::Drop,
                                    t: now,
                                    dur: now - req.arrival,
                                    value: 0.0,
                                });
                            }
                            continue;
                        }
                        if tel.enabled() && tel.sampled(req.id) {
                            tel.record(Span {
                                trace: req.id,
                                member: 0,
                                stage: stage as u32,
                                hop: Hop::Forward,
                                t: now,
                                dur: 0.0,
                                value: (stage + 1) as f64,
                            });
                        }
                        core.forward(stage + 1, req, now);
                    }
                    drive(
                        &mut core,
                        profiles,
                        stage + 1,
                        now,
                        &mut rng,
                        sim.service_noise,
                        tel,
                        0,
                        None,
                        &mut |s| tel.record(s),
                        &mut |t, e| events.push(t, e),
                    );
                } else {
                    for req in &batch {
                        if tel.enabled() && tel.sampled(req.id) {
                            tel.record(Span {
                                trace: req.id,
                                member: 0,
                                stage: stage as u32,
                                hop: Hop::Done,
                                t: now,
                                dur: now - req.arrival,
                                value: 0.0,
                            });
                        }
                        core.complete(req.id, now);
                    }
                }
                // freed replica may unblock this stage's queue
                drive(
                    &mut core,
                    profiles,
                    stage,
                    now,
                    &mut rng,
                    sim.service_noise,
                    tel,
                    0,
                    None,
                    &mut |s| tel.record(s),
                    &mut |t, e| events.push(t, e),
                );
            }
            Event::Adapt => {
                let history = monitor.history(now, crate::predictor::HISTORY);
                let decision = ctl.decide(now, &history);
                journal_decision(tel, now, 0, &decision);
                let observed = monitor.recent_rate(now, interval as usize);
                core.accounting.record_interval(now, &active_cfg, observed, &decision);
                let at = reconfig.stage(now, decision);
                events.push(at, Event::ApplyConfig);
                if now + interval < horizon {
                    events.push(now + interval, Event::Adapt);
                }
            }
            Event::ApplyConfig => {
                while let Some(staged) = reconfig.pop_due(now) {
                    let d = staged.decision;
                    core.apply_config(&d.config, d.lambda_predicted);
                    active_cfg = d.config;
                    for si in 0..n_stages {
                        drive(
                            &mut core,
                            profiles,
                            si,
                            now,
                            &mut rng,
                            sim.service_noise,
                            tel,
                            0,
                            None,
                            &mut |s| tel.record(s),
                            &mut |t, e| events.push(t, e),
                        );
                    }
                }
            }
        }
    }

    // Whatever is still queued/in-flight at the end never completed.
    core.into_accounting().into_metrics(
        system.to_string(),
        profiles.pipeline.clone(),
        trace.name.clone(),
    )
}

/// Start service on `stage` while the core can form batches: each
/// formed batch is scheduled as a `ServiceDone` at the profiled latency
/// (plus optional multiplicative noise); an idle partial batch gets a
/// `QueueCheck` wakeup at its timeout.  `push` is the event sink —
/// the single-pipeline loop pushes [`Event`]s directly, the fleet loop
/// wraps them with its member index.  `sink` receives the sampled
/// spans: the single-pipeline loop records them immediately, the fleet
/// loop buffers them per member and flushes at control-plane barriers
/// so span order is independent of how members interleave (the
/// epoch-parallel determinism contract).
///
/// Span contract (waterfall exactness): for every sampled request,
/// queue-wait starts at its `stage_arrival` and ends at batch
/// formation; exec runs for the (noised) service time; the forward
/// re-stamps `stage_arrival` to the completion instant — so per stage
/// `queue_wait + exec` telescopes exactly to the request's end-to-end
/// latency.
#[allow(clippy::too_many_arguments)]
fn drive(
    core: &mut ClusterCore,
    profiles: &PipelineProfiles,
    stage: usize,
    now: f64,
    rng: &mut SplitMix64,
    noise: f64,
    tel: &Telemetry,
    member: u32,
    mut router: Option<&mut Router>,
    sink: &mut dyn FnMut(Span),
    push: &mut dyn FnMut(f64, Event),
) {
    loop {
        match core.try_form(stage, now) {
            FormOutcome::Busy => return,
            FormOutcome::Idle { next_timeout } => {
                if let Some(at) = next_timeout {
                    if at > now {
                        push(at, Event::QueueCheck { stage });
                    }
                }
                return;
            }
            FormOutcome::Formed(fb) => {
                let vp = &profiles.stages[stage].variants[fb.variant_idx];
                let mut service = vp.latency.latency(fb.batch);
                if noise > 0.0 {
                    let f = 1.0 + noise * rng.next_normal();
                    service *= f.clamp(0.5, 2.0);
                }
                // Front-door pricing: a stage-0 batch consumes its
                // requests' route tags — warm hits and brownouts
                // discount exec, a cross-zone hop pays its penalty.
                if stage == 0 {
                    if let Some(r) = router.as_deref_mut() {
                        let adj = r.on_batch(&fb.requests);
                        service = service * adj.scale + adj.extra;
                    }
                }
                if tel.enabled() {
                    let formed = fb.requests.len() as f64;
                    for req in &fb.requests {
                        if !tel.sampled(req.id) {
                            continue;
                        }
                        let stage = stage as u32;
                        sink(Span {
                            trace: req.id,
                            member,
                            stage,
                            hop: Hop::QueueWait,
                            t: req.stage_arrival,
                            dur: now - req.stage_arrival,
                            value: formed,
                        });
                        sink(Span {
                            trace: req.id,
                            member,
                            stage,
                            hop: Hop::BatchForm,
                            t: now,
                            dur: 0.0,
                            value: fb.batch as f64,
                        });
                        sink(Span {
                            trace: req.id,
                            member,
                            stage,
                            hop: Hop::Exec,
                            t: now,
                            dur: service,
                            value: formed,
                        });
                    }
                }
                push(now + service, Event::ServiceDone { stage, batch: fb.requests });
            }
        }
    }
}

// ---------------------------------------------------------------------------
// The fleet DES driver: N pipelines' events interleaved in one
// virtual-time queue, configurations applied jointly through the
// budget-checked FleetCore.
// ---------------------------------------------------------------------------

/// One fleet-loop event: a member-scoped simulator event or a global
/// adaptation/application/preemption/fault/end event.
#[derive(Debug)]
enum FleetEv {
    Member { member: usize, ev: Event },
    Adapt,
    Apply,
    /// Mid-interval preemption check (the fast path between Adapt
    /// ticks; self-rearming every `interval`, offset by `interval/2`).
    Preempt,
    /// Scripted zone outage: drain the zone's nodes and force an
    /// emergency repack (see [`ZoneFault`]).
    Fault { zone: String },
    End,
}

/// A scripted failure-domain outage for
/// [`FleetDesParams::faults`]: at `at` seconds of virtual time every
/// node in `zone` drains from the pool and the controller re-plans the
/// whole fleet on the survivors (applied immediately — an outage does
/// not wait for the apply delay).
#[derive(Debug, Clone)]
pub struct ZoneFault {
    pub at: f64,
    pub zone: String,
}

/// Result of a fleet DES run: per-member metrics (member order matches
/// the input traces) plus the shared-pool accounting.
#[derive(Debug)]
pub struct FleetRunMetrics {
    pub members: Vec<RunMetrics>,
    /// The replica budget the run ENDED under (the autoscaler may have
    /// moved it from the initial value).  Convenience mirror of
    /// `pool.budget`, kept for the common fixed-pool callers.
    pub budget: u32,
    /// Highest pool occupancy observed, rolling-reconfig overshoot
    /// included (configured replicas never exceeded the budget in
    /// force at the time; this may — see
    /// [`crate::fleet::core::FleetCore::peak_in_use`]).
    pub peak_in_use: u32,
    /// Per-member configured replicas when the run ended (the last
    /// allocation actually applied — what accounting tables report).
    pub final_replicas: Vec<u32>,
    /// Pool-size extremes, resize/preemption counts and the
    /// replica-seconds bought/used cost ledger.
    pub pool: PoolReport,
    /// One entry per zone fault that fired: per member, the minimum
    /// over its stages of replicas that SURVIVED the zone loss under
    /// the placement active at the instant of the fault (what the
    /// zone-spread constraint keeps ≥ 1 for flagged members).  Empty
    /// when no faults were scripted or the pool carries no placement.
    pub zone_fault_min_survivors: Vec<Vec<u32>>,
    /// Per-member front-door counters (routed-per-replica, degraded,
    /// shed, cross-zone, warm hits).  All-default entries when the run
    /// had no router ([`FleetDesParams::router`] = `None`).
    pub router: Vec<RouterStats>,
}

impl FleetRunMetrics {
    pub fn total_requests(&self) -> usize {
        self.members.iter().map(|m| m.requests.len()).sum()
    }

    pub fn total_completed(&self) -> usize {
        self.members.iter().map(|m| m.completed_count()).sum()
    }

    /// Per-member completed-latency histograms (member order matches
    /// `members`).  Mergeable — fold them for a fleet-wide view; the
    /// exact Vec-backed summaries stay untouched.
    pub fn latency_histograms(&self) -> Vec<Histogram> {
        self.members.iter().map(RunMetrics::latency_histogram).collect()
    }

    /// Fleet-wide completed-latency histogram (bucket-wise merge of the
    /// per-member histograms).
    pub fn merged_latency_histogram(&self) -> Histogram {
        let mut all = Histogram::new();
        for h in self.latency_histograms() {
            all.merge(&h);
        }
        all
    }
}

/// The fleet discrete-event loop: the single-pipeline [`run_des`]
/// machinery fanned out over N member cores behind one replica budget.
/// Every member's arrivals, wakeups and completions interleave in one
/// deterministic virtual-time queue; adaptation is a *joint* tick (the
/// controller sees every member's history and returns one decision per
/// member) applied atomically through the budget-checked
/// [`FleetCore::apply`].
///
/// Panics if the controller emits an allocation that violates the
/// budget — controllers built on [`crate::fleet::solver::solve_fleet`]
/// cannot.
///
/// The pool description comes from the controller:
/// [`FleetController::node_inventory`] switches the budget to a
/// heterogeneous node pool (replicas bin-pack on every apply, resizes
/// move whole nodes) and [`FleetController::sla_classes`] keys each
/// member's drop policy and batch-timeout ceiling.  Plain controllers
/// leave both off and run the classic fungible/classless loop.
#[deprecated(note = "use `run_fleet` with `FleetDesParams`, or the `fleet::run::FleetRun` builder")]
#[allow(clippy::too_many_arguments)]
pub fn run_fleet_des(
    profiles: &[PipelineProfiles],
    slas: &[f64],
    interval: f64,
    apply_delay: f64,
    sim: SimConfig,
    ctl: &mut dyn FleetController,
    traces: &[Trace],
    system: &str,
    budget: u32,
) -> FleetRunMetrics {
    run_fleet(
        FleetDesParams {
            profiles,
            slas,
            interval,
            apply_delay,
            sim,
            system,
            budget,
            faults: &[],
            router: None,
            telemetry: None,
        },
        ctl,
        traces,
    )
}

/// [`run_fleet_des`] with scripted failure-domain outages: each
/// [`ZoneFault`] drains its zone's nodes mid-run
/// ([`FleetCore::kill_zone`]), records which members' stages would have
/// survived the loss under the placement in force (the zone-spread
/// guarantee), and asks the controller for an EMERGENCY joint decision
/// on the survivor inventory ([`FleetController::fault`]) applied
/// immediately — no apply delay, the zone is already gone.  Controllers
/// that cannot re-plan (no node inventory) leave the pool untouched.
#[deprecated(note = "use `run_fleet` with `FleetDesParams`, or the `fleet::run::FleetRun` builder")]
#[allow(clippy::too_many_arguments)]
pub fn run_fleet_des_faults(
    profiles: &[PipelineProfiles],
    slas: &[f64],
    interval: f64,
    apply_delay: f64,
    sim: SimConfig,
    ctl: &mut dyn FleetController,
    traces: &[Trace],
    system: &str,
    budget: u32,
    faults: &[ZoneFault],
) -> FleetRunMetrics {
    run_fleet(
        FleetDesParams {
            profiles,
            slas,
            interval,
            apply_delay,
            sim,
            system,
            budget,
            faults,
            router: None,
            telemetry: None,
        },
        ctl,
        traces,
    )
}

/// [`run_fleet_des`] with the flight recorder attached (no faults).
#[deprecated(note = "use `run_fleet` with `FleetDesParams`, or the `fleet::run::FleetRun` builder")]
#[allow(clippy::too_many_arguments)]
pub fn run_fleet_des_traced(
    profiles: &[PipelineProfiles],
    slas: &[f64],
    interval: f64,
    apply_delay: f64,
    sim: SimConfig,
    ctl: &mut dyn FleetController,
    traces: &[Trace],
    system: &str,
    budget: u32,
    tel: &Telemetry,
) -> FleetRunMetrics {
    run_fleet(
        FleetDesParams {
            profiles,
            slas,
            interval,
            apply_delay,
            sim,
            system,
            budget,
            faults: &[],
            router: None,
            telemetry: Some(tel),
        },
        ctl,
        traces,
    )
}

/// [`run_fleet_des_faults`] with the flight recorder attached.
#[deprecated(note = "use `run_fleet` with `FleetDesParams`, or the `fleet::run::FleetRun` builder")]
#[allow(clippy::too_many_arguments)]
pub fn run_fleet_des_faults_traced(
    profiles: &[PipelineProfiles],
    slas: &[f64],
    interval: f64,
    apply_delay: f64,
    sim: SimConfig,
    ctl: &mut dyn FleetController,
    traces: &[Trace],
    system: &str,
    budget: u32,
    faults: &[ZoneFault],
    tel: &Telemetry,
) -> FleetRunMetrics {
    run_fleet(
        FleetDesParams {
            profiles,
            slas,
            interval,
            apply_delay,
            sim,
            system,
            budget,
            faults,
            router: None,
            telemetry: Some(tel),
        },
        ctl,
        traces,
    )
}

/// Options for one fleet DES run — the single entry point
/// ([`run_fleet`]) behind the four historical `run_fleet_des*` names
/// and the [`crate::fleet::run::FleetRun`] builder.
pub struct FleetDesParams<'a> {
    pub profiles: &'a [PipelineProfiles],
    pub slas: &'a [f64],
    /// Adaptation-tick period, virtual seconds.
    pub interval: f64,
    /// Decision → activation delay, virtual seconds.
    pub apply_delay: f64,
    pub sim: SimConfig,
    /// Label stamped on the per-member [`RunMetrics::system`].
    pub system: &'a str,
    /// Replica budget (a controller inventory overrides it with its
    /// replica cap).
    pub budget: u32,
    /// Scripted failure-domain outages, in any order.
    pub faults: &'a [ZoneFault],
    /// Attach the fleet front door: every arrival routes across its
    /// member's stage-0 replicas (and through admission control) before
    /// ingesting.  `None` keeps the classic pre-addressed path,
    /// byte-identical to previous releases.
    pub router: Option<RouterConfig>,
    /// Flight recorder; `None` runs untraced (identical schedule).
    pub telemetry: Option<&'a Telemetry>,
}

/// The fleet DES entry point every named variant delegates to: the
/// sampled requests emit member-tagged spans, and the controller, fleet
/// core and staged reconfig all write the shared decision journal.
/// Tracing is purely observational — the event schedule, RNG draws and
/// metrics are byte-for-byte identical with telemetry on or off, and
/// two traced runs produce byte-identical journals.  With a router
/// attached, routing state lives in each member's lane and all journal
/// aggregation happens at sequential barrier arms, so routed runs are
/// byte-identical at any `IPA_SIM_THREADS` count too.
pub fn run_fleet(
    p: FleetDesParams<'_>,
    ctl: &mut dyn FleetController,
    traces: &[Trace],
) -> FleetRunMetrics {
    let FleetDesParams {
        profiles,
        slas,
        interval,
        apply_delay,
        sim,
        system,
        budget,
        faults,
        router,
        telemetry,
    } = p;
    let off = Telemetry::off();
    let tel = telemetry.unwrap_or(&off);
    ctl.set_journal(tel.journal());
    let n = traces.len();
    assert_eq!(profiles.len(), n, "one profile set per member");
    assert_eq!(slas.len(), n, "one SLA per member");
    // The controller owns the pool description: a node inventory makes
    // the budget its replica cap, and SLA classes key each member's
    // drop policy and batch-timeout ceiling.  Plain controllers return
    // None for both — the classic fungible/classless path.
    let inventory = ctl.node_inventory();
    let classes = ctl.sla_classes();
    if let Some(c) = &classes {
        assert_eq!(c.len(), n, "one SLA class per member");
    }
    let spread = ctl.spread().unwrap_or_default();
    let budget = inventory.as_ref().map_or(budget, |i| i.replica_cap());
    let horizon = traces.iter().map(Trace::seconds).max().unwrap_or(0) as f64;
    // The sharded clock: each member's arrival trace rides its own
    // wheel's O(1) sorted lane, control events ride the global wheel;
    // pop order is byte-for-byte the single-heap order (see
    // `data_plane::wheel`).  `legacy_clock` routes everything through
    // the one global heap instead.
    let mut events: ShardedClock<FleetEv> = ShardedClock::new(n, !sim.legacy_clock);
    // Per-member state bundles.  Each member draws service noise from
    // its OWN seeded stream — a shared stream would make draws depend
    // on how members interleave between barriers, which the parallel
    // epochs deliberately leave unspecified — and buffers its spans
    // and pool-contribution changes for the barrier fold.  Both the
    // legacy pop loop and the epoch driver run on these lanes, so all
    // modes stay byte-identical to each other.
    let mut lanes: Vec<MemberLane> =
        (0..n).map(|m| MemberLane::new(member_seed(sim.seed ^ 0xF1EE7, m))).collect();

    for (m, trace) in traces.iter().enumerate() {
        for (id, &t) in trace.arrivals(member_seed(sim.seed, m)).iter().enumerate() {
            // per-member arrival traces are time-sorted → sorted lane
            events.push_member_sorted(
                m,
                t,
                FleetEv::Member { member: m, ev: Event::Arrival { id: id as u64 } },
            );
        }
    }

    // Joint initial configuration on each trace's first-second rate.
    let first_rates: Vec<f64> = traces.iter().map(|t| t.rate_at(0.0)).collect();
    let inits = ctl.initial(&first_rates);
    assert_eq!(inits.len(), n, "fleet controller must decide per member");
    for (m, d) in inits.iter().enumerate() {
        journal_decision(tel, 0.0, m as u32, d);
    }
    let fleet_inits: Vec<MemberInit> = inits
        .iter()
        .zip(slas)
        .enumerate()
        .map(|(m, (d, &sla))| MemberInit {
            config: d.config.clone(),
            lambda: d.lambda_predicted,
            // the class scales the drop threshold only — attainment
            // metrics keep judging against the true SLA
            drop: DropPolicy::new(sla, sim.drop_enabled)
                .scaled(classes.as_ref().map_or(1.0, |c| c[m].drop_sla_scale())),
            timeout_cap: classes.as_ref().map_or(f64::INFINITY, |c| c[m].timeout_cap(sla)),
        })
        .collect();
    let mut fleet = FleetCore::with_nodes_spread(budget, inventory, &fleet_inits, &spread)
        .expect("fleet controller must respect the replica budget");
    fleet.set_journal(tel.journal());
    let mut reconfig = FleetReconfig::with_migration(apply_delay, ctl.migration_delay());
    reconfig.set_journal(tel.journal());
    let mut active: Vec<PipelineConfig> = inits.iter().map(|d| d.config.clone()).collect();
    let n_stages: Vec<usize> = profiles.iter().map(|p| p.stages.len()).collect();
    // The controller's current pool view.  The physical pool may lag
    // it (a staged shrink not yet landed); staged shrinks below this
    // are stale — a later tick re-grew the budget — and are skipped.
    let mut ctl_budget = budget;
    let mut fault_survivors: Vec<Vec<u32>> = Vec::new();

    // The fleet front door: one router per member lane, so routing
    // state is epoch-worker-private like the RNG stream.  SLAs feed
    // admission pre-scaled by the member's class (the same scaling the
    // drop policy uses); the origin-zone universe is fixed at start —
    // clients in a zone keep sending after it dies.
    if let Some(rc) = &router {
        let zone_names: Vec<String> = fleet
            .inventory()
            .map(|i| i.nodes_by_zone().into_iter().map(|(z, _)| z).collect())
            .unwrap_or_default();
        for (m, lane) in lanes.iter_mut().enumerate() {
            let scale = classes.as_ref().map_or(1.0, |c| c[m].drop_sla_scale());
            lane.router = Some(Router::new(rc.clone(), slas[m] * scale, zone_names.clone()));
        }
        resync_router(&fleet, &mut lanes, &active, 0.0);
    }

    events.push_global(interval, FleetEv::Adapt);
    // Plain fixed-pool controllers never preempt — don't even schedule
    // the mid-interval checks (and their per-member monitor scans).
    if ctl.wants_preemption() && interval * 0.5 < horizon {
        events.push_global(interval * 0.5, FleetEv::Preempt);
    }
    for f in faults {
        if f.at < horizon {
            events.push_global(f.at, FleetEv::Fault { zone: f.zone.clone() });
        }
    }
    events.push_global(horizon, FleetEv::End);

    // Baseline the contribution tracking (the fleet's starting peak
    // already counts the initial replicas).
    let mut cur = vec![0u32; n];
    resync_contrib(&fleet, &mut lanes, &mut cur);

    if sim.legacy_clock {
        // The original fully sequential driver: one event at a time in
        // global (time, seq) order off the single heap.  Kept as the
        // A/B anchor — the epoch driver below reproduces its per-member
        // order byte for byte.
        while let Some((now, fe)) = events.pop() {
            match fe {
                FleetEv::Member { member, ev } => {
                    execute_member_event(
                        fleet.member_mut(member),
                        &mut lanes[member],
                        &profiles[member],
                        n_stages[member],
                        member,
                        now,
                        ev,
                        sim,
                        tel,
                        &mut |t, e| {
                            events.push_member(member, t, FleetEv::Member { member, ev: e })
                        },
                    );
                }
                fe => {
                    fold_barrier(&mut fleet, &mut lanes, &mut cur, tel);
                    let done = execute_global(
                        now,
                        fe,
                        interval,
                        horizon,
                        sim,
                        profiles,
                        &n_stages,
                        &mut fleet,
                        &mut lanes,
                        &mut events,
                        &mut reconfig,
                        ctl,
                        &mut active,
                        &mut ctl_budget,
                        &mut fault_survivors,
                        tel,
                    );
                    resync_contrib(&fleet, &mut lanes, &mut cur);
                    resync_router(&fleet, &mut lanes, &active, now);
                    if done {
                        break;
                    }
                }
            }
        }
    } else {
        // The epoch-parallel driver (default): the global wheel's head
        // is the barrier; every member advances independently strictly
        // up to it on a worker fan-out, then the barrier event executes
        // sequentially.  Byte-identical to the legacy loop at any
        // thread count (see the module docs for the contract).
        let threads = if sim.sequential_epochs {
            1
        } else if sim.sim_threads != 0 {
            sim.sim_threads
        } else {
            sim_threads()
        };
        while let Some(barrier) = events.global_next_due() {
            let base = events.begin_epoch();
            {
                let mut ctxs: Vec<EpochCtx<'_>> = fleet
                    .cores_mut()
                    .iter_mut()
                    .zip(events.lanes_mut().iter_mut())
                    .zip(lanes.iter_mut())
                    .map(|((core, wheel), lane)| EpochCtx { core, wheel, lane })
                    .collect();
                scoped_map_mut(threads, &mut ctxs, |m, ctx| {
                    advance_member(ctx, m, barrier, base, &profiles[m], n_stages[m], sim, tel);
                });
            }
            events.end_epoch(base, n);
            fold_barrier(&mut fleet, &mut lanes, &mut cur, tel);
            let Some((now, fe)) = events.pop_global() else { break };
            let done = execute_global(
                now,
                fe,
                interval,
                horizon,
                sim,
                profiles,
                &n_stages,
                &mut fleet,
                &mut lanes,
                &mut events,
                &mut reconfig,
                ctl,
                &mut active,
                &mut ctl_budget,
                &mut fault_survivors,
                tel,
            );
            resync_contrib(&fleet, &mut lanes, &mut cur);
            resync_router(&fleet, &mut lanes, &active, now);
            if done {
                break;
            }
        }
    }

    fleet.accrue(horizon);
    fleet.note();
    let pool = fleet.pool_report();
    let peak_in_use = fleet.peak_in_use();
    let final_replicas: Vec<u32> =
        (0..n).map(|m| fleet.member(m).configured_replicas()).collect();
    let router_stats: Vec<RouterStats> = lanes
        .iter()
        .map(|l| l.router.as_ref().map(|r| r.stats().clone()).unwrap_or_default())
        .collect();
    let members = fleet
        .into_accountings()
        .into_iter()
        .enumerate()
        .map(|(m, acc)| {
            acc.into_metrics(
                system.to_string(),
                profiles[m].pipeline.clone(),
                traces[m].name.clone(),
            )
        })
        .collect();
    FleetRunMetrics {
        members,
        budget: pool.budget,
        peak_in_use,
        final_replicas,
        pool,
        zone_fault_min_survivors: fault_survivors,
        router: router_stats,
    }
}

/// One member's private, worker-owned simulation state: its service
/// RNG stream, arrival monitor, buffered sampled spans, and the pool
/// contribution log the barrier fold replays — everything a member
/// event touches besides the member's [`ClusterCore`] and event wheel.
/// The whole bundle moves onto one epoch worker as an [`EpochCtx`].
struct MemberLane {
    /// Per-member service-noise stream (`member_seed(seed ^ 0xF1EE7, m)`):
    /// a shared stream would make draws depend on how members
    /// interleave, which parallel epochs deliberately leave unordered.
    rng: SplitMix64,
    /// Arrival-rate history for the controller (read at barriers).
    monitor: Monitor,
    /// Sampled spans buffered in-epoch, flushed to the telemetry ring
    /// at the next barrier in member order.
    spans: Vec<Span>,
    /// `(time, new_contribution)` log: one entry per change to this
    /// member's pool occupancy term, replayed fleet-wide at the
    /// barrier to recover the exact occupancy peak.
    contrib: Vec<(f64, u32)>,
    /// The contribution as of the last log entry (or barrier resync).
    last_contrib: u32,
    /// The member's front door, when [`FleetDesParams::router`] is set:
    /// lane-owned so routing decisions are worker-private in-epoch and
    /// only read (journal ticks, topology resync) at sequential
    /// barriers.
    router: Option<Router>,
}

impl MemberLane {
    fn new(seed: u64) -> MemberLane {
        MemberLane {
            rng: SplitMix64::new(seed),
            monitor: Monitor::new(600),
            spans: Vec::new(),
            contrib: Vec::new(),
            last_contrib: 0,
            router: None,
        }
    }
}

/// This member's term of the fleet occupancy sum — mirrors one core's
/// contribution to [`FleetCore::pool`]'s `in_use` (busy batches keep
/// their slots through a rolling shrink, hence the `max`).
fn member_contrib(core: &ClusterCore) -> u32 {
    core.stages.iter().map(|st| st.busy.max(st.replicas)).sum()
}

/// [`drive`] one member stage against its private lane: RNG draws come
/// from the lane's stream and sampled spans buffer into the lane
/// (flushed at the next barrier), so the call is safe on an epoch
/// worker — it never touches shared state.
#[allow(clippy::too_many_arguments)]
fn drive_lane(
    core: &mut ClusterCore,
    lane: &mut MemberLane,
    profiles: &PipelineProfiles,
    stage: usize,
    now: f64,
    member: usize,
    sim: SimConfig,
    tel: &Telemetry,
    push: &mut dyn FnMut(f64, Event),
) {
    let MemberLane { rng, spans, router, .. } = lane;
    drive(
        core,
        profiles,
        stage,
        now,
        rng,
        sim.service_noise,
        tel,
        member as u32,
        router.as_mut(),
        &mut |s| spans.push(s),
        push,
    );
}

/// Execute ONE member-scoped event against that member's core and
/// lane — the per-member arm of the fleet loop, split out so the
/// legacy pop loop and the epoch drivers share it verbatim: per-member
/// event order and effects are identical across modes by construction.
/// `push` is the member-tagged dynamic-event sink (the shared clock in
/// sequential modes, the member's own wheel in-epoch).
#[allow(clippy::too_many_arguments)]
fn execute_member_event(
    core: &mut ClusterCore,
    lane: &mut MemberLane,
    profiles: &PipelineProfiles,
    n_stages: usize,
    member: usize,
    now: f64,
    ev: Event,
    sim: SimConfig,
    tel: &Telemetry,
    push: &mut dyn FnMut(f64, Event),
) {
    match ev {
        Event::Arrival { id } => {
            lane.monitor.record_arrival(now);
            if tel.enabled() && tel.sampled(id) {
                lane.spans.push(Span {
                    trace: id,
                    member: member as u32,
                    stage: 0,
                    hop: Hop::Arrival,
                    t: now,
                    dur: 0.0,
                    value: 0.0,
                });
            }
            // The front door decides before the queue sees the request:
            // a shed books straight into the §4.5 drop ledger (arrival
            // + drop, never enqueued — `ingress::shed` semantics);
            // routed/degraded requests ingest normally with their tag
            // held for stage-0 batch pricing.
            if matches!(
                lane.router.as_mut().map(|r| r.route(id, now)),
                Some(RouteOutcome::Shed)
            ) {
                core.accounting.record_arrival(id, now);
                core.accounting.record_drop(id);
                if tel.enabled() && tel.sampled(id) {
                    lane.spans.push(Span {
                        trace: id,
                        member: member as u32,
                        stage: 0,
                        hop: Hop::Drop,
                        t: now,
                        dur: 0.0,
                        value: 0.0,
                    });
                }
            } else {
                core.ingest(id, now);
                drive_lane(core, lane, profiles, 0, now, member, sim, tel, push);
            }
        }
        Event::QueueCheck { stage } => {
            drive_lane(core, lane, profiles, stage, now, member, sim, tel, push);
        }
        Event::ServiceDone { stage, batch } => {
            let has_next = stage + 1 < n_stages;
            core.finish_service(stage);
            if has_next {
                for req in batch {
                    if core.accounting.is_dropped(req.id) {
                        if tel.enabled() && tel.sampled(req.id) {
                            lane.spans.push(Span {
                                trace: req.id,
                                member: member as u32,
                                stage: stage as u32,
                                hop: Hop::Drop,
                                t: now,
                                dur: now - req.arrival,
                                value: 0.0,
                            });
                        }
                        continue;
                    }
                    if tel.enabled() && tel.sampled(req.id) {
                        lane.spans.push(Span {
                            trace: req.id,
                            member: member as u32,
                            stage: stage as u32,
                            hop: Hop::Forward,
                            t: now,
                            dur: 0.0,
                            value: (stage + 1) as f64,
                        });
                    }
                    core.forward(stage + 1, req, now);
                }
            } else {
                for req in &batch {
                    if tel.enabled() && tel.sampled(req.id) {
                        lane.spans.push(Span {
                            trace: req.id,
                            member: member as u32,
                            stage: stage as u32,
                            hop: Hop::Done,
                            t: now,
                            dur: now - req.arrival,
                            value: 0.0,
                        });
                    }
                    core.complete(req.id, now);
                }
            }
            if has_next {
                drive_lane(core, lane, profiles, stage + 1, now, member, sim, tel, push);
            }
            // freed replica may unblock this stage's queue
            drive_lane(core, lane, profiles, stage, now, member, sim, tel, push);
        }
        Event::Adapt | Event::ApplyConfig | Event::End => {
            unreachable!("global events are never member-scoped")
        }
    }
    // Log the pool-contribution transition (if any): the barrier fold
    // replays these fleet-wide in time order to recover the occupancy
    // peak without an O(members × stages) scan per event.
    let c = member_contrib(core);
    if c != lane.last_contrib {
        lane.last_contrib = c;
        lane.contrib.push((now, c));
    }
}

/// Sequential barrier fold: flush every lane's buffered spans in
/// member order, then merge the per-member contribution logs in
/// `(time, member)` order and replay the fleet-wide occupancy total to
/// recover its peak since the previous barrier.  Telemetry and fleet
/// metrics are only ever written here and in the global arms — always
/// on the driver thread, in an order independent of the epoch worker
/// count.
fn fold_barrier(fleet: &mut FleetCore, lanes: &mut [MemberLane], cur: &mut [u32], tel: &Telemetry) {
    for lane in lanes.iter_mut() {
        if tel.enabled() {
            for s in lane.spans.drain(..) {
                tel.record(s);
            }
        } else {
            lane.spans.clear();
        }
    }
    let mut changes: Vec<(f64, usize, u32)> = Vec::new();
    for (m, lane) in lanes.iter_mut().enumerate() {
        for (t, v) in lane.contrib.drain(..) {
            changes.push((t, m, v));
        }
    }
    if changes.is_empty() {
        return;
    }
    // Stable by (time, member): same-member entries keep log order and
    // cross-member ties resolve in a fixed order — the replayed peak
    // never depends on how workers interleaved.
    changes.sort_by(|a, b| {
        a.0.partial_cmp(&b.0).unwrap_or(std::cmp::Ordering::Equal).then(a.1.cmp(&b.1))
    });
    let mut total: i64 = cur.iter().map(|&v| i64::from(v)).sum();
    let mut peak = total;
    for (_, m, v) in changes {
        total += i64::from(v) - i64::from(cur[m]);
        cur[m] = v;
        if total > peak {
            peak = total;
        }
    }
    fleet.note_peak(peak.max(0) as u32);
}

/// Re-baseline the contribution tracking from the live cores: a global
/// event can change any member's replicas (apply/preempt/fault), which
/// the member-event logs never see.
fn resync_contrib(fleet: &FleetCore, lanes: &mut [MemberLane], cur: &mut [u32]) {
    for (m, lane) in lanes.iter_mut().enumerate() {
        let c = member_contrib(fleet.member(m));
        lane.last_contrib = c;
        cur[m] = c;
    }
}

/// Re-sync every member's router to the post-global-event topology:
/// stage-0 replica count from the live core, per-replica zone labels
/// from the current packing (replica → node → zone), and the active
/// configuration's per-request service estimate (`l(b)/b`) feeding the
/// admission wait prediction.  Also reclaims tags of requests that were
/// dropped inside batch formation (invisible to the router).  Runs only
/// at sequential barrier arms — a no-op scan when routing is off.
fn resync_router(fleet: &FleetCore, lanes: &mut [MemberLane], active: &[PipelineConfig], now: f64) {
    for (m, lane) in lanes.iter_mut().enumerate() {
        let Some(router) = lane.router.as_mut() else { continue };
        let core = fleet.member(m);
        let replicas = core.stages[0].replicas.max(1) as usize;
        let zones: Vec<String> = match (fleet.last_packing(), fleet.inventory()) {
            (Some(p), Some(inv)) => p
                .placements
                .iter()
                .filter(|pl| pl.member == m && pl.stage == 0)
                .map(|pl| inv.pools[p.shape_of[pl.node]].shape.zone.clone())
                .collect(),
            _ => Vec::new(),
        };
        let sc = &active[m].stages[0];
        let spi = sc.latency / sc.batch.max(1) as f64;
        router.set_topology(replicas, zones, spi);
        router.expire(now);
    }
}

/// Journal each member's front-door counters accumulated since the
/// last adaptation tick: a `route` summary (with the cumulative
/// utilization skew), plus `degrade`/`admit` events when those stages
/// fired.  Runs only on the driver thread at the sequential Adapt arm,
/// so routed journals stay byte-identical at any worker count.
fn journal_route_ticks(tel: &Telemetry, now: f64, lanes: &mut [MemberLane]) {
    for (m, lane) in lanes.iter_mut().enumerate() {
        let Some(router) = lane.router.as_mut() else { continue };
        let tick = router.take_tick();
        if tick.routed == 0 && tick.shed == 0 {
            continue;
        }
        tel.journal().record(
            now,
            "route",
            Json::obj()
                .set("member", m as i64)
                .set("routed", tick.routed as i64)
                .set("cross_zone", tick.cross_zone as i64)
                .set("warm", tick.warm_hits as i64)
                .set("skew", router.stats().utilization_skew()),
        );
        if tick.degraded > 0 {
            tel.journal().record(
                now,
                "degrade",
                Json::obj().set("member", m as i64).set("count", tick.degraded as i64),
            );
        }
        if tick.shed > 0 {
            tel.journal().record(
                now,
                "admit",
                Json::obj().set("member", m as i64).set("shed", tick.shed as i64),
            );
        }
    }
}

/// Everything one epoch worker owns for one member: the member's core,
/// its event wheel, and its lane.  [`scoped_map_mut`] fans these
/// bundles across workers as disjoint `&mut`s — the type system
/// guarantees a worker can only touch its own member's state.
struct EpochCtx<'a> {
    core: &'a mut ClusterCore,
    wheel: &'a mut EventWheel<FleetEv>,
    lane: &'a mut MemberLane,
}

/// The epoch body: drain one member's wheel strictly up to `barrier`
/// on an epoch worker.  Dynamic pushes stamp sequence numbers from
/// this member's private per-epoch sub-range
/// (`base + 1 + member·STRIDE + k`), so stamps — and therefore replay
/// order — are identical no matter how many workers ran the epoch;
/// [`EventWheel::pop_until`] compares full `(time, seq)` keys against
/// the barrier, so events tied with the barrier instant defer exactly
/// as they do in the sequential pop order.
#[allow(clippy::too_many_arguments)]
fn advance_member(
    ctx: &mut EpochCtx<'_>,
    member: usize,
    barrier: (f64, u64),
    base: u64,
    profiles: &PipelineProfiles,
    n_stages: usize,
    sim: SimConfig,
    tel: &Telemetry,
) {
    let seq_base = base + 1 + (member as u64) * EPOCH_SEQ_STRIDE;
    let mut k = 0u64;
    while let Some((now, fe)) = ctx.wheel.pop_until(barrier) {
        let FleetEv::Member { ev, .. } = fe else {
            unreachable!("member wheels carry only member events")
        };
        let EpochCtx { core, wheel, lane } = ctx;
        execute_member_event(
            core,
            lane,
            profiles,
            n_stages,
            member,
            now,
            ev,
            sim,
            tel,
            &mut |t, e| {
                assert!(k + 1 < EPOCH_SEQ_STRIDE, "epoch seq sub-range overflow");
                wheel.push(t, seq_base + k, FleetEv::Member { member, ev: e });
                k += 1;
            },
        );
    }
}

/// Execute one global control event.  Runs strictly sequentially on
/// the driver thread in every mode — the decision journal, controller
/// calls and pool mutations all happen here (or in the barrier fold),
/// never on an epoch worker.  Returns `true` on `End`.
#[allow(clippy::too_many_arguments)]
fn execute_global(
    now: f64,
    fe: FleetEv,
    interval: f64,
    horizon: f64,
    sim: SimConfig,
    profiles: &[PipelineProfiles],
    n_stages: &[usize],
    fleet: &mut FleetCore,
    lanes: &mut [MemberLane],
    events: &mut ShardedClock<FleetEv>,
    reconfig: &mut FleetReconfig,
    ctl: &mut dyn FleetController,
    active: &mut Vec<PipelineConfig>,
    ctl_budget: &mut u32,
    fault_survivors: &mut Vec<Vec<u32>>,
    tel: &Telemetry,
) -> bool {
    let n = lanes.len();
    match fe {
        FleetEv::End => return true,
        FleetEv::Member { .. } => unreachable!("member events never reach the global arm"),
        FleetEv::Adapt => {
            let histories: Vec<Vec<f64>> = lanes
                .iter()
                .map(|l| l.monitor.history(now, crate::predictor::HISTORY))
                .collect();
            // Drift correction: a staged shrink dropped on the way
            // (coalescing, or a preemption clearing the stager)
            // would otherwise strand the physical pool above the
            // controller's view forever — re-sync once nothing is
            // pending (best-effort: never below configured).
            if reconfig.pending_len() == 0 && fleet.budget() > *ctl_budget {
                let _ = fleet.resize_pool_with(
                    now,
                    (*ctl_budget).max(fleet.configured_replicas()),
                    ctl.node_inventory().as_ref(),
                );
            }
            // Autoscaler first: grow the pool immediately so the
            // joint solve can budget against it; defer a shrink
            // until the smaller configurations activate.  The
            // controller's inventory rides along as a MIRROR: with
            // pressure-aware buying the shape it bought no longer
            // follows from the replica target alone.
            let pool_to = ctl.resize(now, &histories);
            if let Some(p) = pool_to {
                if p > fleet.budget() {
                    fleet
                        .resize_pool_with(now, p, ctl.node_inventory().as_ref())
                        .expect("pool growth is always accepted");
                }
                *ctl_budget = p;
            }
            let decisions = ctl.decide(now, &histories);
            assert_eq!(decisions.len(), n, "fleet controller must decide per member");
            for (m, d) in decisions.iter().enumerate() {
                journal_decision(tel, now, m as u32, d);
            }
            for m in 0..n {
                let observed = lanes[m].monitor.recent_rate(now, interval as usize);
                fleet
                    .member_mut(m)
                    .accounting
                    .record_interval(now, &active[m], observed, &decisions[m]);
            }
            journal_route_ticks(tel, now, lanes);
            let shrink_to = pool_to.filter(|&p| p < fleet.budget());
            // Price the decision's churn BEFORE staging it: every
            // replica the sticky re-pack would move charges one
            // migration delay on top of the apply delay.
            let moves = if reconfig.migration_delay > 0.0 {
                let cfgs: Vec<&PipelineConfig> = decisions.iter().map(|d| &d.config).collect();
                fleet.plan_moves(&cfgs)
            } else {
                0
            };
            let at = reconfig.stage(now, decisions, *ctl_budget, shrink_to, moves);
            events.push_global(at, FleetEv::Apply);
            if now + interval < horizon {
                events.push_global(now + interval, FleetEv::Adapt);
            }
        }
        FleetEv::Preempt => {
            let window = (interval * 0.5).max(1.0) as usize;
            let observed: Vec<f64> =
                lanes.iter().map(|l| l.monitor.recent_rate(now, window)).collect();
            if let Some(p) = ctl.preempt(now, &observed) {
                let configs: Vec<(PipelineConfig, f64)> = p
                    .decisions
                    .iter()
                    .map(|d| (d.config.clone(), d.lambda_predicted))
                    .collect();
                fleet.accrue(now);
                fleet.apply(&configs).expect("preemption must respect the replica budget");
                // An applied preemption supersedes anything staged
                // earlier: a stale slow-path decision activating
                // later would silently revert it.
                reconfig.clear();
                // Sync the pool to the controller's view (executes
                // a cleared pending shrink early; best-effort — a
                // rolling drain can hold more than the mirror caps).
                let _ = fleet.resize_pool_with(
                    now,
                    p.budget.max(fleet.configured_replicas()),
                    ctl.node_inventory().as_ref(),
                );
                fleet.note_preemption(&p.from);
                *active = p.decisions.into_iter().map(|d| d.config).collect();
                for m in 0..n {
                    for si in 0..n_stages[m] {
                        drive_member(fleet, lanes, profiles, m, si, now, events, sim, tel);
                    }
                }
            }
            if now + interval < horizon {
                events.push_global(now + interval, FleetEv::Preempt);
            }
        }
        FleetEv::Apply => {
            // pop_due coalesces: every due stage drains, only the
            // newest applies.
            while let Some(staged) = reconfig.pop_due(now) {
                let configs: Vec<(PipelineConfig, f64)> = staged
                    .decisions
                    .iter()
                    .map(|d| (d.config.clone(), d.lambda_predicted))
                    .collect();
                fleet.accrue(now);
                fleet.apply(&configs).expect("fleet controller must respect the replica budget");
                // A shrink is only safe when nothing bigger is
                // still in flight: it must cover the controller's
                // current budget AND every pending stage's solve
                // budget (with apply-delay > interval, stale
                // shrinks and larger mid-flight configurations can
                // interleave).
                if let Some(p) = staged.shrink_to {
                    let in_flight = (*ctl_budget).max(reconfig.max_pending_budget().unwrap_or(0));
                    if p >= in_flight {
                        // best-effort mirror sync: a newer, even
                        // smaller controller view can undercut the
                        // configuration just applied — then this
                        // shrink waits for ITS stage instead
                        let _ = fleet.resize_pool_with(now, p, ctl.node_inventory().as_ref());
                    }
                }
                *active = staged.decisions.into_iter().map(|d| d.config).collect();
                for m in 0..n {
                    for si in 0..n_stages[m] {
                        drive_member(fleet, lanes, profiles, m, si, now, events, sim, tel);
                    }
                }
            }
        }
        FleetEv::Fault { zone } => {
            // Drain the zone from a CLONE first: the controller
            // must bless the survivor pool (re-plan on it) before
            // the physical pool is touched — a controller that
            // cannot re-plan leaves the fleet intact.
            let survivor = fleet.inventory().map(|inv| {
                let mut s = inv.clone();
                (s.drain_zone(&zone), s)
            });
            if let Some((drained, survivor)) = survivor {
                if drained > 0 {
                    let observed: Vec<f64> = lanes
                        .iter()
                        .map(|l| l.monitor.recent_rate(now, interval.max(1.0) as usize))
                        .collect();
                    if let Some(ds) = ctl.fault(now, survivor, &observed) {
                        assert_eq!(ds.len(), n, "fault decisions are per member");
                        // record what the active placement would
                        // have kept alive through the loss — the
                        // zone-spread guarantee under test
                        fault_survivors.push(fleet.zone_survivors(&zone).unwrap_or_default());
                        fleet.kill_zone(now, &zone);
                        // stale staged decisions were solved on the
                        // dead pool; the emergency apply supersedes
                        reconfig.clear();
                        let configs: Vec<(PipelineConfig, f64)> = ds
                            .iter()
                            .map(|d| (d.config.clone(), d.lambda_predicted))
                            .collect();
                        fleet
                            .apply(&configs)
                            .expect("fault decision solved under the survivor pool");
                        *ctl_budget = fleet.budget();
                        *active = ds.into_iter().map(|d| d.config).collect();
                        for m in 0..n {
                            for si in 0..n_stages[m] {
                                drive_member(fleet, lanes, profiles, m, si, now, events, sim, tel);
                            }
                        }
                    }
                }
            }
        }
    }
    false
}

/// [`drive`] for one fleet member at a BARRIER (post-reconfiguration
/// queue kicks): events come back member-tagged through the shared
/// clock, spans record directly (barriers are sequential), and pool
/// peak usage is noted only when a batch actually formed — the only
/// driver-side transition here that can raise `in_use`.
#[allow(clippy::too_many_arguments)]
fn drive_member(
    fleet: &mut FleetCore,
    lanes: &mut [MemberLane],
    profiles: &[PipelineProfiles],
    member: usize,
    stage: usize,
    now: f64,
    events: &mut ShardedClock<FleetEv>,
    sim: SimConfig,
    tel: &Telemetry,
) {
    let MemberLane { rng, router, .. } = &mut lanes[member];
    let mut formed = false;
    drive(
        fleet.member_mut(member),
        &profiles[member],
        stage,
        now,
        rng,
        sim.service_noise,
        tel,
        member as u32,
        router.as_mut(),
        &mut |s| tel.record(s),
        &mut |t, e| {
            formed |= matches!(e, Event::ServiceDone { .. });
            // dynamic events land on the member wheel's heap lane
            events.push_member(member, t, FleetEv::Member { member, ev: e });
        },
    );
    if formed {
        fleet.note();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::adapter::{Adapter, AdapterConfig, Policy};
    use crate::models::accuracy::AccuracyMetric;
    use crate::models::pipelines;
    use crate::predictor::ReactivePredictor;
    use crate::profiler::analytic::pipeline_profiles;
    use crate::workload::tracegen::Pattern;

    fn make_sim(pipeline: &str, policy: Policy) -> Simulation {
        let spec = pipelines::by_name(pipeline).unwrap();
        let prof = pipeline_profiles(&spec);
        let adapter = Adapter::new(
            spec,
            prof,
            policy,
            AdapterConfig::default(),
            Box::new(ReactivePredictor::default()),
        );
        Simulation::new(adapter, SimConfig { seed: 3, ..Default::default() })
    }

    #[test]
    fn video_steady_low_mostly_within_sla() {
        let mut sim = make_sim("video", Policy::Ipa(AccuracyMetric::Pas));
        let trace = Trace::synthetic(Pattern::SteadyLow, 240);
        let m = sim.run(&trace);
        assert!(m.requests.len() > 800, "{}", m.requests.len());
        let att = m.sla_attainment();
        assert!(att > 0.85, "attainment {att}");
        assert!(m.drop_rate() < 0.1, "drops {}", m.drop_rate());
        assert!(!m.intervals.is_empty());
    }

    #[test]
    fn deterministic_given_seed() {
        let t = Trace::synthetic(Pattern::SteadyLow, 120);
        let m1 = make_sim("video", Policy::Fa2Low).run(&t);
        let m2 = make_sim("video", Policy::Fa2Low).run(&t);
        assert_eq!(m1.requests.len(), m2.requests.len());
        assert_eq!(m1.latencies(), m2.latencies());
    }

    #[test]
    fn fa2_low_cheaper_than_fa2_high() {
        let t = Trace::synthetic(Pattern::SteadyLow, 180);
        let low = make_sim("video", Policy::Fa2Low).run(&t);
        let high = make_sim("video", Policy::Fa2High).run(&t);
        assert!(low.avg_cost() < high.avg_cost());
        assert!(low.avg_pas() < high.avg_pas());
    }

    #[test]
    fn ipa_between_fa2_bounds_on_pas() {
        // §5.2: FA2-low/high provide the PAS floor/ceiling.
        let t = Trace::synthetic(Pattern::Fluctuating, 240);
        let low = make_sim("video", Policy::Fa2Low).run(&t);
        let high = make_sim("video", Policy::Fa2High).run(&t);
        let ipa = make_sim("video", Policy::Ipa(AccuracyMetric::Pas)).run(&t);
        assert!(ipa.avg_pas() >= low.avg_pas() - 1e-9, "{} vs {}", ipa.avg_pas(), low.avg_pas());
        assert!(ipa.avg_pas() <= high.avg_pas() + 1e-9);
    }

    #[test]
    fn completions_never_precede_arrivals() {
        let t = Trace::synthetic(Pattern::Bursty, 150);
        let m = make_sim("video", Policy::Ipa(AccuracyMetric::Pas)).run(&t);
        for r in &m.requests {
            if let Some(c) = r.completion {
                assert!(c >= r.arrival);
            }
        }
    }

    #[test]
    fn drops_bounded_by_2sla_rule() {
        // With dropping enabled, completed latencies stay under ~2×SLA
        // plus one service time.
        let t = Trace::synthetic(Pattern::Bursty, 200);
        let mut sim = make_sim("video", Policy::Fa2Low);
        sim.sim.drop_enabled = true;
        let m = sim.run(&t);
        let max_lat = m.latencies().iter().fold(0.0f64, |a, &b| a.max(b));
        assert!(max_lat < 3.0 * m.sla, "max latency {max_lat} vs sla {}", m.sla);
    }

    #[test]
    fn three_stage_pipeline_runs() {
        let t = Trace::synthetic(Pattern::SteadyLow, 120);
        let m = make_sim("nlp", Policy::Ipa(AccuracyMetric::Pas)).run(&t);
        assert!(m.sla_attainment() > 0.5, "{}", m.sla_attainment());
    }

    #[test]
    fn decision_log_captures_initial_and_ticks() {
        let t = Trace::synthetic(Pattern::SteadyLow, 120);
        let (m, log) = make_sim("video", Policy::Fa2Low).run_logged(&t);
        // one initial decision + one per recorded interval
        assert_eq!(log.decisions.len(), m.intervals.len() + 1);
        assert!(!log.decisions[0].config.stages.is_empty());
    }

    // ---- fleet driver ----------------------------------------------------

    use crate::fleet::solver::FleetAdapter;
    use crate::fleet::spec::FleetSpec;
    use crate::predictor::Predictor;

    fn fleet_fixture(budget: u32, seconds: usize) -> (FleetAdapter, Vec<f64>, Vec<Trace>) {
        let fleet = FleetSpec::demo3();
        let specs = fleet.specs().unwrap();
        let profs: Vec<_> = specs.iter().map(pipeline_profiles).collect();
        let slas: Vec<f64> = specs.iter().map(|s| s.sla_e2e()).collect();
        let predictors: Vec<Box<dyn Predictor + Send>> = specs
            .iter()
            .map(|_| Box::new(ReactivePredictor::default()) as Box<dyn Predictor + Send>)
            .collect();
        let adapter = FleetAdapter::new(
            specs,
            profs,
            AccuracyMetric::Pas,
            budget,
            AdapterConfig::default(),
            predictors,
        )
        .unwrap();
        let traces = fleet.traces(seconds);
        (adapter, slas, traces)
    }

    #[test]
    fn fleet_des_runs_three_pipelines_under_budget() {
        let (mut adapter, slas, traces) = fleet_fixture(24, 200);
        let profiles = adapter.profiles.clone();
        let sim = SimConfig { seed: 5, ..Default::default() };
        let fm = run_fleet(
            FleetDesParams {
                profiles: &profiles,
                slas: &slas,
                interval: 10.0,
                apply_delay: 8.0,
                sim,
                system: "fleet-ipa",
                budget: 24,
                faults: &[],
                router: None,
                telemetry: None,
            },
            &mut adapter,
            &traces,
        );
        assert_eq!(fm.members.len(), 3);
        for m in &fm.members {
            assert!(m.requests.len() > 100, "{}: {}", m.workload, m.requests.len());
            assert!(!m.intervals.is_empty());
            assert!(m.completed_count() > 0, "{}", m.workload);
        }
        assert_eq!(fm.budget, 24);
        // the budget invariant held on every reconfig, so the only
        // overshoot is rolling-update drain
        assert!(fm.peak_in_use >= 7, "pool was used: {}", fm.peak_in_use);
        assert_eq!(fm.final_replicas.len(), 3);
        assert!(fm.final_replicas.iter().sum::<u32>() <= 24, "{:?}", fm.final_replicas);
    }

    #[test]
    fn fleet_des_deterministic_given_seed() {
        let run = || {
            let (mut adapter, slas, traces) = fleet_fixture(20, 120);
            let profiles = adapter.profiles.clone();
            let sim = SimConfig { seed: 9, ..Default::default() };
            run_fleet(
                FleetDesParams {
                    profiles: &profiles,
                    slas: &slas,
                    interval: 10.0,
                    apply_delay: 8.0,
                    sim,
                    system: "fleet",
                    budget: 20,
                    faults: &[],
                    router: None,
                    telemetry: None,
                },
                &mut adapter,
                &traces,
            )
        };
        let a = run();
        let b = run();
        assert_eq!(a.total_requests(), b.total_requests());
        for (ma, mb) in a.members.iter().zip(&b.members) {
            assert_eq!(ma.requests, mb.requests);
        }
    }
}
