//! Event queue for the discrete-event drivers: a min-heap on
//! (time, sequence) — the sequence number makes simultaneous events
//! deterministic (FIFO among ties).
//!
//! [`TimedQueue`] is generic over the event payload so the
//! single-pipeline loop ([`EventQueue`] = `TimedQueue<Event>`) and the
//! fleet loop (member-tagged events) share the same deterministic
//! ordering machinery.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

use crate::queueing::Request;

/// Simulator event kinds (single-pipeline loop; the fleet loop wraps
/// these with a member index).
#[derive(Debug, Clone, PartialEq)]
pub enum Event {
    /// Request `id` arrives at the pipeline entrance.
    Arrival { id: u64 },
    /// A replica of `stage` finishes the batch it was serving (the
    /// admitted requests ride along for forwarding/completion).
    ServiceDone { stage: usize, batch: Vec<Request> },
    /// Re-check `stage`'s queue (batch timeout wakeup).
    QueueCheck { stage: usize },
    /// Run the adapter.
    Adapt,
    /// The oldest staged reconfiguration becomes active (see
    /// [`crate::cluster::reconfig::Reconfig`]).
    ApplyConfig,
    /// End of simulation.
    End,
}

#[derive(Debug, Clone)]
struct Entry<E> {
    time: f64,
    seq: u64,
    event: E,
}

impl<E> PartialEq for Entry<E> {
    fn eq(&self, other: &Self) -> bool {
        self.time == other.time && self.seq == other.seq
    }
}
impl<E> Eq for Entry<E> {}

impl<E> Ord for Entry<E> {
    fn cmp(&self, other: &Self) -> Ordering {
        // reversed for min-heap semantics on BinaryHeap (max-heap)
        other
            .time
            .partial_cmp(&self.time)
            .unwrap_or(Ordering::Equal)
            .then(other.seq.cmp(&self.seq))
    }
}
impl<E> PartialOrd for Entry<E> {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

/// Deterministic min-time event queue over an arbitrary payload.
#[derive(Debug)]
pub struct TimedQueue<E> {
    heap: BinaryHeap<Entry<E>>,
    seq: u64,
}

impl<E> Default for TimedQueue<E> {
    fn default() -> Self {
        TimedQueue { heap: BinaryHeap::new(), seq: 0 }
    }
}

impl<E> TimedQueue<E> {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, time: f64, event: E) {
        self.seq += 1;
        self.heap.push(Entry { time, seq: self.seq, event });
    }

    pub fn pop(&mut self) -> Option<(f64, E)> {
        self.heap.pop().map(|e| (e.time, e.event))
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }
}

/// The single-pipeline event queue.
pub type EventQueue = TimedQueue<Event>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pops_in_time_order() {
        let mut q = EventQueue::new();
        q.push(3.0, Event::Adapt);
        q.push(1.0, Event::Arrival { id: 1 });
        q.push(2.0, Event::QueueCheck { stage: 0 });
        let times: Vec<f64> = std::iter::from_fn(|| q.pop().map(|(t, _)| t)).collect();
        assert_eq!(times, vec![1.0, 2.0, 3.0]);
    }

    #[test]
    fn ties_fifo() {
        let mut q = EventQueue::new();
        q.push(1.0, Event::Arrival { id: 10 });
        q.push(1.0, Event::Arrival { id: 20 });
        q.push(1.0, Event::Arrival { id: 30 });
        let ids: Vec<u64> = std::iter::from_fn(|| {
            q.pop().map(|(_, e)| match e {
                Event::Arrival { id } => id,
                _ => unreachable!(),
            })
        })
        .collect();
        assert_eq!(ids, vec![10, 20, 30]);
    }

    #[test]
    fn len_and_empty() {
        let mut q = EventQueue::new();
        assert!(q.is_empty());
        q.push(0.0, Event::End);
        assert_eq!(q.len(), 1);
        q.pop();
        assert!(q.is_empty());
    }

    #[test]
    fn generic_payload_orders_the_same() {
        // the fleet loop's member-tagged payload
        let mut q: TimedQueue<(usize, &str)> = TimedQueue::new();
        q.push(2.0, (1, "b"));
        q.push(1.0, (0, "a"));
        q.push(1.0, (2, "c"));
        assert_eq!(q.pop(), Some((1.0, (0, "a"))));
        assert_eq!(q.pop(), Some((1.0, (2, "c"))));
        assert_eq!(q.pop(), Some((2.0, (1, "b"))));
    }
}
