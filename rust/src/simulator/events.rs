//! Event queue for the discrete-event simulator: a min-heap on
//! (time, sequence) — the sequence number makes simultaneous events
//! deterministic (FIFO among ties).

use std::cmp::Ordering;
use std::collections::BinaryHeap;

use crate::queueing::Request;

/// Simulator event kinds.
#[derive(Debug, Clone, PartialEq)]
pub enum Event {
    /// Request `id` arrives at the pipeline entrance.
    Arrival { id: u64 },
    /// A replica of `stage` finishes the batch it was serving (the
    /// admitted requests ride along for forwarding/completion).
    ServiceDone { stage: usize, batch: Vec<Request> },
    /// Re-check `stage`'s queue (batch timeout wakeup).
    QueueCheck { stage: usize },
    /// Run the adapter.
    Adapt,
    /// The oldest staged reconfiguration becomes active (see
    /// [`crate::cluster::reconfig::Reconfig`]).
    ApplyConfig,
    /// End of simulation.
    End,
}

#[derive(Debug, Clone)]
struct Entry {
    time: f64,
    seq: u64,
    event: Event,
}

impl PartialEq for Entry {
    fn eq(&self, other: &Self) -> bool {
        self.time == other.time && self.seq == other.seq
    }
}
impl Eq for Entry {}

impl Ord for Entry {
    fn cmp(&self, other: &Self) -> Ordering {
        // reversed for min-heap semantics on BinaryHeap (max-heap)
        other
            .time
            .partial_cmp(&self.time)
            .unwrap_or(Ordering::Equal)
            .then(other.seq.cmp(&self.seq))
    }
}
impl PartialOrd for Entry {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

/// Deterministic min-time event queue.
#[derive(Debug, Default)]
pub struct EventQueue {
    heap: BinaryHeap<Entry>,
    seq: u64,
}

impl EventQueue {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, time: f64, event: Event) {
        self.seq += 1;
        self.heap.push(Entry { time, seq: self.seq, event });
    }

    pub fn pop(&mut self) -> Option<(f64, Event)> {
        self.heap.pop().map(|e| (e.time, e.event))
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pops_in_time_order() {
        let mut q = EventQueue::new();
        q.push(3.0, Event::Adapt);
        q.push(1.0, Event::Arrival { id: 1 });
        q.push(2.0, Event::QueueCheck { stage: 0 });
        let times: Vec<f64> = std::iter::from_fn(|| q.pop().map(|(t, _)| t)).collect();
        assert_eq!(times, vec![1.0, 2.0, 3.0]);
    }

    #[test]
    fn ties_fifo() {
        let mut q = EventQueue::new();
        q.push(1.0, Event::Arrival { id: 10 });
        q.push(1.0, Event::Arrival { id: 20 });
        q.push(1.0, Event::Arrival { id: 30 });
        let ids: Vec<u64> = std::iter::from_fn(|| {
            q.pop().map(|(_, e)| match e {
                Event::Arrival { id } => id,
                _ => unreachable!(),
            })
        })
        .collect();
        assert_eq!(ids, vec![10, 20, 30]);
    }

    #[test]
    fn len_and_empty() {
        let mut q = EventQueue::new();
        assert!(q.is_empty());
        q.push(0.0, Event::End);
        assert_eq!(q.len(), 1);
        q.pop();
        assert!(q.is_empty());
    }
}
