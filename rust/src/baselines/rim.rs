//! RIM baseline (Hu et al., IoTDI'21) as used in the paper's §5.1:
//! model switching to adapt to load, **no autoscaling** — the paper
//! statically sets each stage's replica count to a high value and (for
//! fairness) adds batching.
//!
//! Decision rule: with replicas fixed at `fixed_replicas`, choose the
//! most accurate (variant, batch) combination that satisfies the
//! latency SLA and the throughput constraint `n·h ≥ λ`.  Under bursts
//! RIM must trade accuracy down to keep throughput — the Fig. 8-12
//! behaviour — while its cost stays pinned high.

use crate::baselines::fa2::build_config;
use crate::models::registry::BATCH_SIZES;
use crate::optimizer::ip::{PipelineConfig, Problem};
use crate::queueing::worst_case_delay;

/// RIM settings.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RimParams {
    /// Static replica count per stage ("set to a high value", §5.1).
    pub fixed_replicas: u32,
}

impl Default for RimParams {
    fn default() -> Self {
        RimParams { fixed_replicas: 8 }
    }
}

/// RIM decision.  Exhaustive over (variant × batch) per stage with a
/// cross-stage latency check (spaces are ≤ 42 options/stage).
/// Falls back to the lightest variant at throughput-best batch when the
/// SLA cannot be met at the fixed scale.
pub fn decide(p: &Problem, rp: RimParams) -> PipelineConfig {
    let s = p.profiles.stages.len();
    let sla = p.spec.sla_e2e();
    let n = rp.fixed_replicas;

    // Per-stage candidate lists: (variant_idx, batch, latency, accuracy)
    // that satisfy the throughput constraint at fixed n.
    let mut cands: Vec<Vec<(usize, usize, f64, f64)>> = Vec::with_capacity(s);
    for st in &p.profiles.stages {
        let mut list = Vec::new();
        for (vi, vp) in st.variants.iter().enumerate() {
            for &b in &BATCH_SIZES {
                let tput = n as f64 * vp.latency.throughput(b);
                if tput < p.lambda {
                    continue;
                }
                let l = vp.latency.latency(b) + worst_case_delay(b, p.lambda);
                list.push((vi, b, l, vp.variant.accuracy));
            }
        }
        // keep, per variant, the lowest-latency batch choice first; sort
        // descending accuracy then ascending latency for greedy pruning
        list.sort_by(|a, b| b.3.partial_cmp(&a.3).unwrap().then(a.2.partial_cmp(&b.2).unwrap()));
        cands.push(list);
    }

    if cands.iter().all(|c| !c.is_empty()) {
        // Exhaustive with the latency budget; maximize PAS (product).
        let mut best: Option<(f64, Vec<(usize, usize)>)> = None;
        let mut idx = vec![0usize; s];
        'outer: loop {
            let mut lat = 0.0;
            let mut acc = 1.0;
            for (si, &ci) in idx.iter().enumerate() {
                let (_, _, l, a) = cands[si][ci];
                lat += l;
                acc *= a / 100.0;
            }
            if lat <= sla && best.as_ref().is_none_or(|(b, _)| acc > *b) {
                best = Some((
                    acc,
                    idx.iter()
                        .enumerate()
                        .map(|(si, &ci)| (cands[si][ci].0, cands[si][ci].1))
                        .collect(),
                ));
            }
            let mut d = 0;
            loop {
                idx[d] += 1;
                if idx[d] < cands[d].len() {
                    break;
                }
                idx[d] = 0;
                d += 1;
                if d == s {
                    break 'outer;
                }
            }
        }
        if let Some((_, picks)) = best {
            let vids: Vec<usize> = picks.iter().map(|&(v, _)| v).collect();
            let bn: Vec<(usize, u32)> = picks.iter().map(|&(_, b)| (b, n)).collect();
            return build_config(p, &vids, &bn);
        }
    }

    // Fallback: lightest variant, throughput-best batch, fixed scale.
    let vids: Vec<usize> = p
        .profiles
        .stages
        .iter()
        .map(|st| {
            st.variants
                .iter()
                .enumerate()
                .min_by(|(_, a), (_, b)| {
                    a.latency.latency(1).partial_cmp(&b.latency.latency(1)).unwrap()
                })
                .map(|(i, _)| i)
                .unwrap()
        })
        .collect();
    let bn: Vec<(usize, u32)> = p
        .profiles
        .stages
        .iter()
        .zip(&vids)
        .map(|(st, &vi)| (st.variants[vi].latency.best_batch(), n))
        .collect();
    build_config(p, &vids, &bn)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::pipelines;
    use crate::profiler::analytic::pipeline_profiles;

    #[test]
    fn rim_cost_pinned_by_fixed_scale() {
        let spec = pipelines::by_name("audio-qa").unwrap();
        let prof = pipeline_profiles(&spec);
        let rp = RimParams { fixed_replicas: 8 };
        let lo = decide(&Problem::new(&spec, &prof, 2.0), rp);
        for st in &lo.stages {
            assert_eq!(st.replicas, 8);
        }
    }

    #[test]
    fn rim_downgrades_variants_under_load() {
        // Fig. 8 behaviour: under bursts RIM trades accuracy for
        // throughput because it cannot scale.
        let spec = pipelines::by_name("video").unwrap();
        let prof = pipeline_profiles(&spec);
        let rp = RimParams { fixed_replicas: 4 };
        let low_load = decide(&Problem::new(&spec, &prof, 2.0), rp);
        let high_load = decide(&Problem::new(&spec, &prof, 60.0), rp);
        assert!(high_load.pas <= low_load.pas, "{} -> {}", low_load.pas, high_load.pas);
    }

    #[test]
    fn rim_more_expensive_than_ipa_at_low_load() {
        // §5.4: RIM's latency advantage comes at ~3x resource cost.
        let spec = pipelines::by_name("audio-qa").unwrap();
        let prof = pipeline_profiles(&spec);
        let p = Problem::new(&spec, &prof, 3.0);
        let rim = decide(&p, RimParams { fixed_replicas: 8 });
        let ipa = crate::optimizer::ip::solve(&p).unwrap().0;
        assert!(rim.cost > ipa.cost, "rim {} vs ipa {}", rim.cost, ipa.cost);
    }

    #[test]
    fn rim_meets_sla_when_possible() {
        let spec = pipelines::by_name("video").unwrap();
        let prof = pipeline_profiles(&spec);
        let cfg = decide(&Problem::new(&spec, &prof, 10.0), RimParams::default());
        assert!(cfg.latency_e2e <= spec.sla_e2e() + 1e-9);
    }

    #[test]
    fn rim_picks_accurate_variants_at_low_load() {
        let spec = pipelines::by_name("video").unwrap();
        let prof = pipeline_profiles(&spec);
        let cfg = decide(&Problem::new(&spec, &prof, 1.0), RimParams { fixed_replicas: 8 });
        // With ample fixed capacity RIM should sit at/near the top PAS.
        assert!(cfg.pas > 50.0, "pas {}", cfg.pas);
    }
}
