//! FA2 baseline (Razavi et al., RTAS'22) as used in the paper's §5.1:
//! joint batching + horizontal scaling with a **fixed model variant**
//! per stage (FA2 has no model switching).  `FA2-low` pins the lightest
//! variant, `FA2-high` a heavy combination.
//!
//! Given the fixed variants, the optimal batch/replica assignment
//! minimizes `β·Σ n·R + δ·Σ b` under the Eq. 10 constraints; the space
//! is |B|^S ≤ 343, so exact enumeration replaces FA2's dynamic program
//! (same optimum, simpler — noted in DESIGN.md).

use crate::models::registry::BATCH_SIZES;
use crate::optimizer::ip::{PipelineConfig, Problem, StageConfig};
use crate::queueing::worst_case_delay;

/// Which fixed variant each stage uses.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum VariantPin {
    /// Lightest (cheapest base allocation, then fastest) — FA2-low.
    Lightest,
    /// Most accurate — FA2-high (the paper pins "a heavy combination";
    /// we use the top variant).
    Heaviest,
}

/// Pick the pinned variant index for a stage.
fn pin_index(p: &Problem, stage_idx: usize, pin: VariantPin) -> usize {
    let vars = &p.profiles.stages[stage_idx].variants;
    match pin {
        VariantPin::Lightest => vars
            .iter()
            .enumerate()
            .min_by(|(_, a), (_, b)| {
                (a.cost_per_replica(), a.latency.latency(1))
                    .partial_cmp(&(b.cost_per_replica(), b.latency.latency(1)))
                    .unwrap()
            })
            .map(|(i, _)| i)
            .unwrap(),
        VariantPin::Heaviest => vars
            .iter()
            .enumerate()
            .max_by(|(_, a), (_, b)| {
                a.variant.accuracy.partial_cmp(&b.variant.accuracy).unwrap()
            })
            .map(|(i, _)| i)
            .unwrap(),
    }
}

/// FA2 decision: min-cost batches/replicas for the pinned variants.
/// Infeasible inputs fall back to (throughput-best batch, replica cap)
/// per stage — FA2 sheds the rest via dropping, like the paper's runs
/// under bursts.
pub fn decide(p: &Problem, pin: VariantPin) -> PipelineConfig {
    let s = p.profiles.stages.len();
    let pins: Vec<usize> = (0..s).map(|i| pin_index(p, i, pin)).collect();
    let sla = p.spec.sla_e2e();
    let w = p.spec.weights;

    // Enumerate batch combos (odometer), track min-cost feasible combo.
    let mut idx = vec![0usize; s];
    let mut best: Option<(f64, Vec<(usize, u32)>)> = None; // (cost, [(batch, n)])
    'outer: loop {
        let mut lat = 0.0;
        let mut cost = 0.0;
        let mut picks = Vec::with_capacity(s);
        let mut feasible = true;
        for (si, &bi) in idx.iter().enumerate() {
            let b = BATCH_SIZES[bi];
            let vp = &p.profiles.stages[si].variants[pins[si]];
            let l = vp.latency.latency(b);
            lat += l + worst_case_delay(b, p.lambda);
            let tput = vp.latency.throughput(b);
            let n = (p.lambda / tput).ceil().max(1.0) as u32;
            if n > p.max_replicas {
                feasible = false;
                break;
            }
            cost += n as f64 * vp.cost_per_replica() * w.beta + w.delta * b as f64;
            picks.push((b, n));
        }
        if feasible && lat <= sla && best.as_ref().is_none_or(|(c, _)| cost < *c) {
            best = Some((cost, picks));
        }
        // odometer
        let mut d = 0;
        loop {
            idx[d] += 1;
            if idx[d] < BATCH_SIZES.len() {
                break;
            }
            idx[d] = 0;
            d += 1;
            if d == s {
                break 'outer;
            }
        }
    }

    let picks = match best {
        Some((_, picks)) => picks,
        None => (0..s)
            .map(|si| {
                let vp = &p.profiles.stages[si].variants[pins[si]];
                let b = vp.latency.best_batch();
                (b, p.max_replicas)
            })
            .collect(),
    };

    build_config(p, &pins, &picks)
}

/// Assemble a [`PipelineConfig`] from explicit per-stage picks.
pub fn build_config(
    p: &Problem,
    variant_idx: &[usize],
    picks: &[(usize, u32)],
) -> PipelineConfig {
    let w = p.spec.weights;
    let mut stages = Vec::new();
    let mut cost = 0.0;
    let mut batch_sum = 0usize;
    let mut lat = 0.0;
    let mut pas_frac = 1.0;
    let mut resources = crate::resources::ResourceVec::ZERO;
    for (si, (&vi, &(b, n))) in variant_idx.iter().zip(picks).enumerate() {
        let vp = &p.profiles.stages[si].variants[vi];
        let l = vp.latency.latency(b);
        stages.push(StageConfig {
            variant_idx: vi,
            variant_key: vp.variant.key(),
            batch: b,
            replicas: n,
            cost: n as f64 * vp.cost_per_replica(),
            accuracy: vp.variant.accuracy,
            latency: l,
            resources: vp.resources_per_replica(),
        });
        cost += n as f64 * vp.cost_per_replica();
        batch_sum += b;
        lat += l + worst_case_delay(b, p.lambda);
        pas_frac *= vp.variant.accuracy / 100.0;
        resources = resources.add(vp.resources_per_replica().scale(n as f64));
    }
    PipelineConfig {
        stages,
        pas: 100.0 * pas_frac,
        cost,
        batch_sum,
        objective: w.alpha * 100.0 * pas_frac - w.beta * cost - w.delta * batch_sum as f64,
        latency_e2e: lat,
        resources,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::pipelines;
    use crate::profiler::analytic::pipeline_profiles;

    fn prob(name: &str, lambda: f64) -> (crate::models::pipelines::PipelineSpec, crate::profiler::profile::PipelineProfiles, f64) {
        let spec = pipelines::by_name(name).unwrap();
        let prof = pipeline_profiles(&spec);
        (spec, prof, lambda)
    }

    #[test]
    fn low_pins_lightest_high_pins_heaviest() {
        let (spec, prof, l) = prob("video", 10.0);
        let p = Problem::new(&spec, &prof, l);
        let low = decide(&p, VariantPin::Lightest);
        let high = decide(&p, VariantPin::Heaviest);
        assert_eq!(low.stages[0].variant_key, "detect.yolov5n");
        assert_eq!(high.stages[0].variant_key, "detect.yolov5x");
        assert!(high.pas > low.pas);
    }

    #[test]
    fn fa2_low_cheapest_fa2_high_most_accurate() {
        // §5.2: FA2-low/high bracket the PAS range; FA2-high costs more.
        let (spec, prof, l) = prob("sum-qa", 12.0);
        let p = Problem::new(&spec, &prof, l);
        let low = decide(&p, VariantPin::Lightest);
        let high = decide(&p, VariantPin::Heaviest);
        assert!(high.cost > low.cost);
    }

    #[test]
    fn meets_sla_when_feasible() {
        for name in ["video", "audio-qa", "audio-sent", "sum-qa", "nlp"] {
            let (spec, prof, l) = prob(name, 8.0);
            let p = Problem::new(&spec, &prof, l);
            let cfg = decide(&p, VariantPin::Lightest);
            assert!(cfg.latency_e2e <= spec.sla_e2e() + 1e-9, "{name}");
        }
    }

    #[test]
    fn ipa_objective_at_least_fa2() {
        // IPA searches a superset of FA2's space: its objective can
        // never be worse than either FA2 pin.
        let (spec, prof, l) = prob("video", 15.0);
        let p = Problem::new(&spec, &prof, l);
        let ipa = crate::optimizer::ip::solve(&p).unwrap().0;
        for pin in [VariantPin::Lightest, VariantPin::Heaviest] {
            let fa2 = decide(&p, pin);
            if fa2.latency_e2e <= spec.sla_e2e() {
                assert!(ipa.objective >= fa2.objective - 1e-9, "{pin:?}");
            }
        }
    }

    #[test]
    fn replicas_scale_with_load() {
        let (spec, prof, _) = prob("video", 0.0);
        let p5 = Problem::new(&spec, &prof, 5.0);
        let p30 = Problem::new(&spec, &prof, 30.0);
        let lo = decide(&p5, VariantPin::Lightest);
        let hi = decide(&p30, VariantPin::Lightest);
        let lo_n: u32 = lo.stages.iter().map(|s| s.replicas).sum();
        let hi_n: u32 = hi.stages.iter().map(|s| s.replicas).sum();
        assert!(hi_n >= lo_n);
    }
}
