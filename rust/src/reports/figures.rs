//! Regeneration of the paper's figures (7–18) on the simulator
//! substrate.  Every function returns the printed report as a String
//! (the CLI and the bench harness write it to stdout / bench_output).

use std::sync::Arc;

use crate::baselines::rim::RimParams;
use crate::coordinator::adapter::{Adapter, AdapterConfig, Policy};
use crate::metrics::RunMetrics;
use crate::models::accuracy::AccuracyMetric;
use crate::models::pipelines::{self, ObjectiveWeights, PipelineSpec};
use crate::models::registry::{StageType, Variant};
use crate::optimizer::ip::{self, Problem};
use crate::predictor::{LstmPredictor, OraclePredictor, Predictor, ReactivePredictor};
use crate::profiler::analytic::pipeline_profiles;
use crate::profiler::fit::ProfileSamples;
use crate::profiler::profile::{PipelineProfiles, StageProfile, VariantProfile};
use crate::runtime::pool::ExecutorPool;
use crate::simulator::sim::{SimConfig, Simulation};
use crate::workload::trace::Trace;
use crate::workload::tracegen::Pattern;

/// Which predictor a run uses.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PredKind {
    Lstm,
    Reactive,
    Oracle,
}

impl PredKind {
    pub fn name(self) -> &'static str {
        match self {
            PredKind::Lstm => "lstm",
            PredKind::Reactive => "reactive",
            PredKind::Oracle => "oracle",
        }
    }
}

/// Report options.
#[derive(Clone)]
pub struct EvalOpts {
    /// Trace length, seconds.
    pub seconds: usize,
    /// Artifact dir for the LSTM predictor (None → reactive fallback).
    pub artifact_dir: Option<String>,
    /// Shared executor pool (lazily created).
    pool: Option<Arc<ExecutorPool>>,
}

impl EvalOpts {
    pub fn new(seconds: usize, artifact_dir: Option<String>) -> Self {
        EvalOpts { seconds, artifact_dir, pool: None }
    }

    /// Quick defaults for tests.
    pub fn quick() -> Self {
        EvalOpts::new(180, None)
    }

    fn pool(&mut self) -> Option<Arc<ExecutorPool>> {
        if self.pool.is_none() {
            if let Some(dir) = &self.artifact_dir {
                match ExecutorPool::new(dir, 1) {
                    Ok(p) => self.pool = Some(Arc::new(p)),
                    Err(e) => {
                        crate::log_warn!("reports", "no artifact pool: {e:#}");
                        self.artifact_dir = None;
                    }
                }
            }
        }
        self.pool.clone()
    }

    fn make_predictor(&mut self, kind: PredKind, trace: &Trace) -> Box<dyn Predictor + Send> {
        match kind {
            PredKind::Oracle => Box::new(OraclePredictor { trace: trace.clone() }),
            PredKind::Reactive => Box::new(ReactivePredictor::default()),
            PredKind::Lstm => match self.pool() {
                Some(p) => Box::new(LstmPredictor::new(p.lstm_closure())),
                None => Box::new(ReactivePredictor::default()),
            },
        }
    }
}

/// Run one (pipeline, policy, pattern, predictor) cell on the simulator.
pub fn run_cell(
    pipeline: &str,
    policy: Policy,
    pattern: Pattern,
    pred: PredKind,
    opts: &mut EvalOpts,
) -> RunMetrics {
    let spec = pipelines::by_name(pipeline).expect("pipeline");
    run_cell_spec(&spec, policy, pattern, pred, opts)
}

/// Like [`run_cell`] with an explicit (possibly reweighted) spec.
pub fn run_cell_spec(
    spec: &PipelineSpec,
    policy: Policy,
    pattern: Pattern,
    pred: PredKind,
    opts: &mut EvalOpts,
) -> RunMetrics {
    let prof = pipeline_profiles(spec);
    let trace = Trace::synthetic(pattern, opts.seconds);
    let predictor = opts.make_predictor(pred, &trace);
    let adapter = Adapter::new(spec.clone(), prof, policy, AdapterConfig::default(), predictor);
    let mut sim = Simulation::new(adapter, SimConfig::default());
    sim.run(&trace)
}

const SYSTEMS: [(&str, fn() -> Policy); 4] = [
    ("IPA", || Policy::Ipa(AccuracyMetric::Pas)),
    ("FA2-low", || Policy::Fa2Low),
    ("FA2-high", || Policy::Fa2High),
    ("RIM", || Policy::Rim(RimParams { fixed_replicas: 8 })),
];

fn cell_row(name: &str, m: &RunMetrics) -> String {
    format!(
        "  {:<9} PAS {:>6.2}  cost {:>7.1}  SLA-att {:>5.1}%  drops {:>4.1}%  p99 {:>6.2}s  switches {}\n",
        name,
        m.avg_pas(),
        m.avg_cost(),
        m.sla_attainment() * 100.0,
        m.drop_rate() * 100.0,
        m.latency_summary().p99,
        m.variant_switches(),
    )
}

/// Figs. 8–12: per-pipeline temporal + average analysis across the four
/// workloads and four systems.
pub fn fig_e2e(pipeline: &str, opts: &mut EvalOpts) -> String {
    let mut out = format!("Fig 8-12 style evaluation: pipeline={pipeline}\n");
    for pattern in Pattern::EVAL {
        out.push_str(&format!("\nworkload: {}\n", pattern.name()));
        for (name, mk) in SYSTEMS {
            let m = run_cell(pipeline, mk(), pattern, PredKind::Lstm, opts);
            out.push_str(&cell_row(name, &m));
            // temporal excerpt: every 6th interval
            if pattern == Pattern::Bursty {
                let pts: Vec<String> = m
                    .intervals
                    .iter()
                    .step_by(6)
                    .map(|i| format!("(t={:.0} pas={:.1} cost={:.0})", i.t, i.pas, i.cost))
                    .collect();
                out.push_str(&format!("           temporal: {}\n", pts.join(" ")));
            }
        }
    }
    out
}

/// Fig. 7: the four trace excerpts with LSTM predictions.
pub fn fig7(opts: &mut EvalOpts) -> String {
    let mut out = String::from("Fig 7: workload excerpts + LSTM predictions\n");
    for pattern in Pattern::EVAL {
        let trace = Trace::synthetic(pattern, opts.seconds);
        let mut pred = opts.make_predictor(PredKind::Lstm, &trace);
        let mut preds = Vec::new();
        let mut truths = Vec::new();
        let mut t = 130.0;
        while t + 20.0 < trace.seconds() as f64 {
            let hist_start = (t as usize).saturating_sub(120);
            let history = &trace.rates[hist_start..t as usize];
            preds.push(pred.predict(t, history));
            truths.push(trace.max_in_window(t, 20.0));
            t += 10.0;
        }
        let smape = crate::util::stats::smape(&preds, &truths);
        let peak = trace.peak();
        let mean = crate::util::stats::mean(&trace.rates);
        out.push_str(&format!(
            "  {:<12} mean {:>5.1} peak {:>5.1} RPS | predictor {} SMAPE {:>5.1}% (paper LSTM: 6.6%)\n",
            pattern.name(),
            mean,
            peak,
            pred.name(),
            smape
        ));
    }
    out
}

/// Fig. 13: solver decision time vs pipeline length × variants/stage.
pub fn fig13() -> String {
    let mut out = String::from(
        "Fig 13: IP decision time (ms) vs #stages x #variants (paper: <2s at 10x10)\n",
    );
    out.push_str(&format!("{:<8}", "stages"));
    let variant_counts = [2usize, 4, 6, 8, 10];
    for m in variant_counts {
        out.push_str(&format!("{:>10}", format!("m={m}")));
    }
    out.push('\n');
    for s in [2usize, 4, 6, 8, 10] {
        out.push_str(&format!("{:<8}", s));
        for m in variant_counts {
            let (spec, prof) = synthetic_problem(s, m);
            let p = Problem::new(&spec, &prof, 12.0);
            let t0 = std::time::Instant::now();
            let _ = ip::solve(&p);
            out.push_str(&format!("{:>10.2}", t0.elapsed().as_secs_f64() * 1e3));
        }
        out.push('\n');
    }
    out
}

/// Build a synthetic s-stage, m-variants/stage problem (Fig. 13 grid).
pub fn synthetic_problem(s: usize, m: usize) -> (PipelineSpec, PipelineProfiles) {
    let mut stages_prof = Vec::new();
    for si in 0..s {
        let mut variants = Vec::new();
        for vi in 0..m {
            // leaked static variants: bench-only, bounded by grid size
            let v: &'static Variant = Box::leak(Box::new(Variant {
                stage_type: StageType::Detect,
                name: Box::leak(format!("syn-{si}-{vi}").into_boxed_str()),
                params_m: 2.0 + 10.0 * vi as f64,
                base_alloc: 1 + (vi as u32 / 2),
                accuracy: 50.0 + 40.0 * vi as f64 / m.max(2) as f64,
            }));
            let l1 = 0.05 + 0.08 * vi as f64;
            let mut samples = ProfileSamples::default();
            for &b in &crate::models::registry::BATCH_SIZES {
                samples.push(b, l1 * crate::profiler::analytic::batch_shape(b));
            }
            variants.push(VariantProfile { variant: v, latency: samples.fit().unwrap() });
        }
        stages_prof.push(StageProfile { stage_type: StageType::Detect, variants });
    }
    let spec = PipelineSpec {
        name: "synthetic",
        stages: vec![StageType::Detect; s],
        weights: ObjectiveWeights { alpha: 5.0, beta: 0.5, delta: 1e-6 },
        stage_slas: vec![2.0; s],
    };
    (
        spec,
        PipelineProfiles { pipeline: "synthetic".into(), stages: stages_prof },
    )
}

/// Fig. 14: accuracy/cost trade-off under different (α, β) preferences.
pub fn fig14(opts: &mut EvalOpts) -> String {
    let mut out = String::from("Fig 14: cost vs PAS under objective preferences\n");
    let scenarios: [(&str, f64, f64); 3] = [
        ("resource-prio", 0.2, 10.0),
        ("balanced", 1.0, 1.0),
        ("accuracy-prio", 10.0, 0.1),
    ];
    for spec0 in pipelines::all() {
        out.push_str(&format!("  {}\n", spec0.name));
        for (label, am, bm) in scenarios {
            let mut spec = spec0.clone();
            spec.weights.alpha *= am;
            spec.weights.beta *= bm;
            let m = run_cell_spec(
                &spec,
                Policy::Ipa(AccuracyMetric::Pas),
                Pattern::Fluctuating,
                PredKind::Lstm,
                opts,
            );
            out.push_str(&format!(
                "    {:<15} cost {:>7.1}  PAS {:>6.2}\n",
                label,
                m.avg_cost(),
                m.avg_pas()
            ));
        }
    }
    out
}

/// Fig. 15: end-to-end latency CDFs (bursty workload).
pub fn fig15(opts: &mut EvalOpts) -> String {
    let mut out = String::from("Fig 15: E2E latency CDF (bursty)\n");
    for spec in pipelines::all() {
        out.push_str(&format!("  {}\n", spec.name));
        for (name, mk) in SYSTEMS {
            let m = run_cell(spec.name, mk(), Pattern::Bursty, PredKind::Lstm, opts);
            let s = m.latency_summary();
            out.push_str(&format!(
                "    {:<9} p50 {:>6.2}s p90 {:>6.2}s p99 {:>6.2}s (sla {:.2}s, n={})\n",
                name,
                s.p50,
                crate::util::stats::percentile(&m.latencies(), 90.0),
                s.p99,
                m.sla,
                s.n
            ));
        }
    }
    out
}

/// Fig. 16: predictor ablation — SLA violations and cost for
/// LSTM vs reactive vs oracle on the bursty workload.
pub fn fig16(opts: &mut EvalOpts) -> String {
    let mut out = String::from("Fig 16: predictor ablation (bursty, IPA policy)\n");
    for spec in pipelines::all() {
        out.push_str(&format!("  {}\n", spec.name));
        for kind in [PredKind::Lstm, PredKind::Reactive, PredKind::Oracle] {
            let m = run_cell(
                spec.name,
                Policy::Ipa(AccuracyMetric::Pas),
                Pattern::Bursty,
                kind,
                opts,
            );
            out.push_str(&format!(
                "    {:<9} violations {:>5.2}%  cost {:>7.1}  pred-SMAPE {:>6.1}%\n",
                kind.name(),
                m.violation_rate() * 100.0,
                m.avg_cost(),
                m.prediction_smape()
            ));
        }
    }
    out
}

/// Figs. 17/18 (Appendix C): PAS′ metric replication on video + sum-qa.
pub fn fig17(opts: &mut EvalOpts) -> String {
    let mut out = String::from("Fig 17/18: PAS' (normalized-sum) metric replication\n");
    for pipeline in ["video", "sum-qa"] {
        out.push_str(&format!("  {pipeline}\n"));
        for pattern in [Pattern::Bursty, Pattern::SteadyLow] {
            out.push_str(&format!("    workload {}\n", pattern.name()));
            for (name, mk) in [
                ("IPA-PAS'", (|| Policy::Ipa(AccuracyMetric::PasPrime)) as fn() -> Policy),
                ("FA2-low", || Policy::Fa2Low),
                ("FA2-high", || Policy::Fa2High),
            ] {
                let m = run_cell(pipeline, mk(), pattern, PredKind::Lstm, opts);
                out.push_str(&cell_row(name, &m));
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig13_fast_at_10x10() {
        let (spec, prof) = synthetic_problem(10, 10);
        let p = Problem::new(&spec, &prof, 12.0);
        let t0 = std::time::Instant::now();
        let r = ip::solve(&p);
        let dt = t0.elapsed().as_secs_f64();
        assert!(r.is_some());
        assert!(dt < 2.0, "paper budget: {dt}s");
    }

    #[test]
    fn synthetic_problem_shapes() {
        let (spec, prof) = synthetic_problem(3, 4);
        assert_eq!(spec.stages.len(), 3);
        assert_eq!(prof.stages.len(), 3);
        assert_eq!(prof.stages[0].variants.len(), 4);
    }

    #[test]
    fn run_cell_smoke() {
        let mut opts = EvalOpts::new(120, None);
        let m = run_cell(
            "video",
            Policy::Fa2Low,
            Pattern::SteadyLow,
            PredKind::Reactive,
            &mut opts,
        );
        assert!(m.requests.len() > 300);
        assert!(!m.intervals.is_empty());
    }
}
