//! Regeneration of the paper's tables (2, 3, 5, 6) from our substrate.
//! Each function prints rows in the paper's format; absolute values come
//! from the calibrated hardware model / analytic profiles, so the
//! *shape* (orderings, ratios, crossovers) is the reproduction target.

use crate::fleet::core::PoolReport;
use crate::metrics::RunMetrics;
use crate::resources::ResourceVec;
use crate::models::pipelines;
use crate::models::registry::{by_key, variants_of, StageType};
use crate::profiler::analytic::{hw_latency, hw_throughput, pipeline_profiles};
use crate::profiler::base_alloc;

/// Fig. 2: latency / throughput / accuracy across the ResNet family
/// (batch 1, one core).
pub fn fig2() -> String {
    let mut out = String::new();
    out.push_str("Fig 2: ResNet family, batch=1, 1 CPU core\n");
    out.push_str(&format!(
        "{:<12} {:>12} {:>16} {:>10}\n",
        "model", "latency(ms)", "throughput(RPS)", "accuracy"
    ));
    for v in variants_of(StageType::Classify) {
        let l = hw_latency(v, 1, 1);
        out.push_str(&format!(
            "{:<12} {:>12.1} {:>16.1} {:>10.2}\n",
            v.name,
            l * 1e3,
            1.0 / l,
            v.accuracy
        ));
    }
    out
}

/// Table 2: ResNet18/50 latency + throughput under 1/4/8 cores.
pub fn table2() -> String {
    let r18 = by_key("classify.resnet18").unwrap();
    let r50 = by_key("classify.resnet50").unwrap();
    let mut out = String::new();
    out.push_str("Table 2: ResNet18 vs ResNet50 under CPU allocations (batch=1)\n");
    out.push_str(&format!(
        "{:<6} {:>14} {:>12} {:>14} {:>12}\n",
        "cores", "r18 lat(ms)", "r18 RPS", "r50 lat(ms)", "r50 RPS"
    ));
    for &c in &[1u32, 4, 8] {
        out.push_str(&format!(
            "{:<6} {:>14.1} {:>12.1} {:>14.1} {:>12.1}\n",
            c,
            hw_latency(r18, 1, c) * 1e3,
            hw_throughput(r18, 1, c),
            hw_latency(r50, 1, c) * 1e3,
            hw_throughput(r50, 1, c),
        ));
    }
    out
}

/// Table 3: two-stage (video) configuration options — variants ×
/// batch {1, 8} with scale, latency, cost and accuracy.
pub fn table3() -> String {
    let spec = pipelines::by_name("video").unwrap();
    let prof = pipeline_profiles(&spec);
    let mut out = String::new();
    out.push_str("Table 3: video pipeline configuration options (paper's A/B rows)\n");
    out.push_str(&format!(
        "{:<24} {:>6} {:>6} {:>12} {:>8} {:>9}\n",
        "variant", "scale", "batch", "latency(ms)", "cost", "accuracy"
    ));
    let rows: [(usize, &str, u32); 4] = [
        (0, "detect.yolov5n", 2),
        (0, "detect.yolov5m", 5),
        (1, "classify.resnet18", 2),
        (1, "classify.resnet50", 3),
    ];
    for (si, key, scale) in rows {
        for &b in &[1usize, 8] {
            let vp = prof.stages[si]
                .variants
                .iter()
                .find(|v| v.variant.key() == key)
                .unwrap();
            out.push_str(&format!(
                "{:<24} {:>6} {:>6} {:>12.0} {:>8} {:>9.2}\n",
                key,
                scale,
                b,
                vp.latency.latency(b) * 1e3,
                format!("{}x{}", scale, vp.variant.base_alloc),
                vp.variant.accuracy
            ));
        }
    }
    out
}

/// Table 5: Eq. 1 base allocations for the YOLO variants under RPS
/// thresholds {5, 10, 15} (× = infeasible within the 32-core cap).
pub fn table5() -> String {
    let vs = variants_of(StageType::Detect);
    let sla_s = pipelines::by_name("video").unwrap().stage_slas[0];
    let mut out = String::new();
    out.push_str("Table 5: base CPU allocation per YOLOv5 variant (Eq. 1)\n");
    out.push_str(&format!("{:<6}", "load"));
    for v in &vs {
        out.push_str(&format!("{:>10}", v.name));
    }
    out.push('\n');
    for &th in &[5.0, 10.0, 15.0] {
        out.push_str(&format!("{:<6}", th as u32));
        for a in base_alloc::table_row(&vs, th, sla_s, 8) {
            match a {
                Some(c) => out.push_str(&format!("{c:>10}")),
                None => out.push_str(&format!("{:>10}", "x")),
            }
        }
        out.push('\n');
    }
    out
}

/// Table 6: per-stage and end-to-end SLAs computed from the profiles
/// via the Swayam rule (calibrated to the paper's values).
pub fn table6() -> String {
    let mut out = String::new();
    out.push_str("Table 6: per-stage and E2E SLAs (seconds)\n");
    out.push_str(&format!(
        "{:<14} {:>9} {:>9} {:>9} {:>9}\n",
        "pipeline", "stage1", "stage2", "stage3", "E2E"
    ));
    for spec in pipelines::all() {
        let prof = pipeline_profiles(&spec);
        let slas: Vec<f64> = prof.stages.iter().map(|s| s.stage_sla()).collect();
        let mut row = format!("{:<14}", spec.name);
        for i in 0..3 {
            match slas.get(i) {
                Some(s) => row.push_str(&format!(" {s:>9.2}")),
                None => row.push_str(&format!(" {:>9}", "x")),
            }
        }
        row.push_str(&format!(" {:>9.2}\n", prof.sla_e2e()));
        out.push_str(&row);
    }
    out
}

/// Per-pipeline fleet accounting: one row per member (requests,
/// completions, drops, SLA attainment, average PAS/cost, replica
/// share, replicas lost to preemption), a fleet totals row, and the
/// shared-pool block — final size, size range over the run with the
/// resize count, preemption events, the replica-migration/zone-kill
/// line, and the replica-second cost ledger (bought vs used with the
/// utilization percentage); node-backed pools add per-shape node
/// counts, the node-seconds ledger and (when zoned) per-zone node
/// counts.  `names`, `metrics` and `shares` are per member in fleet
/// order; `pool` is the run's [`PoolReport`].
pub fn fleet_table(
    names: &[String],
    metrics: &[RunMetrics],
    shares: &[u32],
    pool: &PoolReport,
) -> String {
    let mut out = String::new();
    out.push_str("Fleet accounting: per-pipeline outcomes over one shared replica pool\n");
    out.push_str(&format!(
        "{:<16} {:<10} {:<14} {:>8} {:>8} {:>7} {:>7} {:>8} {:>8} {:>6} {:>8}\n",
        "member", "pipeline", "workload", "reqs", "done", "drop%", "att%", "avgPAS", "avgCost",
        "repl", "preempt"
    ));
    let mut tot_reqs = 0usize;
    let mut tot_done = 0usize;
    let mut tot_cost = 0.0f64;
    for (i, ((name, m), &share)) in names.iter().zip(metrics).zip(shares).enumerate() {
        out.push_str(&format!(
            "{:<16} {:<10} {:<14} {:>8} {:>8} {:>6.1}% {:>6.1}% {:>8.2} {:>8.1} {:>6} {:>8}\n",
            name,
            m.pipeline,
            m.workload,
            m.requests.len(),
            m.completed_count(),
            m.drop_rate() * 100.0,
            m.sla_attainment() * 100.0,
            m.avg_pas(),
            m.avg_cost(),
            share,
            pool.preempted.get(i).copied().unwrap_or(0),
        ));
        tot_reqs += m.requests.len();
        tot_done += m.completed_count();
        tot_cost += m.avg_cost();
    }
    // 33 = the drop%/att%/avgPAS/avgCost block (7+1+7+1+8+1+8) so the
    // total cost lands under the avgCost column.
    out.push_str(&format!(
        "{:<16} {:<10} {:<14} {:>8} {:>8} {:>33.1} {:>6} {:>8}\n",
        "TOTAL",
        "-",
        "-",
        tot_reqs,
        tot_done,
        tot_cost,
        shares.iter().sum::<u32>(),
        pool.preempted.iter().sum::<u32>(),
    ));
    out.push_str(&format!(
        "shared pool: {} of {} replicas granted | size {}..{} over the run ({} resizes) | \
         {} preemptions\n",
        shares.iter().sum::<u32>(),
        pool.budget,
        pool.pool_min,
        pool.pool_max,
        pool.resizes,
        pool.preemptions,
    ));
    // Placement churn: replicas moved between nodes across the run's
    // reconfigurations (sticky packing keeps it low; fungible pools
    // report 0), plus zone outages the run absorbed.
    out.push_str(&format!(
        "replica migrations: {} | zone kills: {}\n",
        pool.migrations, pool.zone_kills,
    ));
    out.push_str(&format!(
        "pool cost: {:.0} replica-s bought, {:.0} used ({:.0}% utilized)\n",
        pool.bought_replica_secs,
        pool.used_replica_secs,
        pool.utilization() * 100.0,
    ));
    // Vector breakdown of the fleet's time-averaged demand: the scalar
    // avgCost column above is the cpu axis; memory and accel bind
    // through packing, so they are reported alongside.
    let rv: ResourceVec =
        metrics.iter().fold(ResourceVec::ZERO, |a, m| a.add(m.avg_resources()));
    out.push_str(&format!(
        "cost vector: {:>8.1} cpu cores | {:>8.1} GB mem | {:>6.1} accel slots \
         (time-averaged fleet total)\n",
        rv.cpu_cores, rv.memory_gb, rv.accel_slots,
    ));
    // Node-backed pools: final per-shape counts and the node-seconds
    // ledger (fungible pools print nothing extra).
    if !pool.nodes_final.is_empty() {
        let shapes: Vec<String> =
            pool.nodes_final.iter().map(|(name, count)| format!("{count}x{name}")).collect();
        out.push_str(&format!("pool nodes: {}\n", shapes.join(" + ")));
        let secs: Vec<String> =
            pool.node_secs.iter().map(|(name, s)| format!("{name}={s:.0}")).collect();
        out.push_str(&format!("node-seconds bought per shape: {}\n", secs.join(", ")));
    }
    // Failure domains: final node counts per zone (zoned pools only).
    if !pool.nodes_by_zone.is_empty() {
        let zones: Vec<String> = pool
            .nodes_by_zone
            .iter()
            .map(|(zone, count)| format!("{zone}={count} nodes"))
            .collect();
        out.push_str(&format!("pool zones: {}\n", zones.join(", ")));
    }
    out
}

/// Fleet front-door accounting: one row per member with the routed /
/// degraded / shed split, per-replica utilization skew and the
/// cross-zone + sticky warm-hit counters, plus a totals row.  `names`
/// and `stats` are per member in fleet order ([`RouterStats`] from
/// either clock's report).  Returns a one-line notice when no request
/// went through a router (pre-addressed ingress runs), so callers can
/// print unconditionally.  Additive next to [`fleet_table`] — the
/// pinned fleet-table layout is untouched.
pub fn router_table(names: &[String], stats: &[crate::metrics::RouterStats]) -> String {
    let mut out = String::new();
    if stats.iter().all(|s| s.total_routed() == 0 && s.shed == 0) {
        out.push_str("fleet front door: disabled (pre-addressed ingress)\n");
        return out;
    }
    out.push_str("Fleet front door: per-member routing + admission outcomes\n");
    out.push_str(&format!(
        "{:<16} {:>8} {:>6} {:>7} {:>8} {:>6} {:>9} {:>6}\n",
        "member", "routed", "repl", "skew%", "degraded", "shed", "crosszone", "warm"
    ));
    let mut tot = crate::metrics::RouterStats::default();
    for (name, s) in names.iter().zip(stats) {
        out.push_str(&format!(
            "{:<16} {:>8} {:>6} {:>6.1}% {:>8} {:>6} {:>9} {:>6}\n",
            name,
            s.total_routed(),
            s.routed.len(),
            s.utilization_skew() * 100.0,
            s.degraded,
            s.shed,
            s.cross_zone,
            s.warm_hits,
        ));
        tot.routed.push(s.total_routed());
        tot.degraded += s.degraded;
        tot.shed += s.shed;
        tot.cross_zone += s.cross_zone;
        tot.warm_hits += s.warm_hits;
    }
    out.push_str(&format!(
        "{:<16} {:>8} {:>6} {:>7} {:>8} {:>6} {:>9} {:>6}\n",
        "TOTAL",
        tot.total_routed(),
        "-",
        "-",
        tot.degraded,
        tot.shed,
        tot.cross_zone,
        tot.warm_hits,
    ));
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig2_rows_ordered_by_latency() {
        let s = fig2();
        assert!(s.contains("resnet18"));
        assert!(s.contains("resnet152"));
        // resnet18 line must appear before resnet152 (ascending size)
        assert!(s.find("resnet18").unwrap() < s.find("resnet152").unwrap());
    }

    #[test]
    fn table2_has_three_core_rows() {
        let s = table2();
        assert_eq!(s.lines().count(), 5);
    }

    #[test]
    fn table5_shape() {
        let s = table5();
        // heavier YOLO variants never need fewer cores going right
        for line in s.lines().skip(2) {
            let cols: Vec<&str> = line.split_whitespace().skip(1).collect();
            let vals: Vec<u32> = cols
                .iter()
                .map(|c| c.parse::<u32>().unwrap_or(64))
                .collect();
            for w in vals.windows(2) {
                assert!(w[0] <= w[1], "{line}");
            }
        }
    }

    #[test]
    fn table6_matches_paper_values() {
        let s = table6();
        assert!(s.contains("6.89"), "{s}");
        assert!(s.contains("9.23"));
        assert!(s.contains("17.6"));
    }

    #[test]
    fn table3_contains_paper_variants() {
        let s = table3();
        assert!(s.contains("detect.yolov5n"));
        assert!(s.contains("classify.resnet50"));
    }

    #[test]
    fn fleet_table_rows_and_pool_line() {
        use crate::metrics::{IntervalRecord, RequestRecord};
        let mk = |pipeline: &str, workload: &str| RunMetrics {
            system: "fleet-ipa".into(),
            pipeline: pipeline.into(),
            workload: workload.into(),
            requests: vec![
                RequestRecord { id: 0, arrival: 0.0, completion: Some(0.5) },
                RequestRecord { id: 1, arrival: 0.0, completion: None },
            ],
            intervals: vec![IntervalRecord {
                t: 10.0,
                pas: 80.0,
                cost: 6.0,
                resources: ResourceVec::new(6.0, 12.5, 1.0),
                lambda_observed: 5.0,
                lambda_predicted: 6.0,
                decision_time: 0.001,
                variants: vec!["v".into()],
            }],
            sla: 1.0,
        };
        let names = vec!["video-edge".to_string(), "nlp-batchline".to_string()];
        let metrics = vec![mk("video", "bursty"), mk("nlp", "steady_low")];
        let pool = PoolReport {
            budget: 24,
            pool_min: 20,
            pool_max: 26,
            peak_in_use: 18,
            resizes: 3,
            migrations: 4,
            zone_kills: 0,
            preemptions: 2,
            preempted: vec![0, 5],
            bought_replica_secs: 4800.0,
            used_replica_secs: 3600.0,
            nodes_final: Vec::new(),
            node_secs: Vec::new(),
            nodes_by_zone: Vec::new(),
        };
        let s = fleet_table(&names, &metrics, &[9, 7], &pool);
        assert!(s.contains("video-edge"), "{s}");
        assert!(s.contains("nlp-batchline"));
        assert!(s.contains("TOTAL"));
        assert!(s.contains("16 of 24 replicas"), "{s}");
        assert!(s.contains("size 20..26 over the run (3 resizes)"), "{s}");
        assert!(s.contains("2 preemptions"), "{s}");
        assert!(s.contains("replica migrations: 4 | zone kills: 0"), "{s}");
        assert!(s.contains("4800 replica-s bought, 3600 used (75% utilized)"), "{s}");
        // vector breakdown line: 2 members × (6c, 12.5g, 1a)
        assert!(s.contains("cost vector:"), "{s}");
        assert!(s.contains("12.0 cpu cores"), "{s}");
        assert!(s.contains("25.0 GB mem"), "{s}");
        assert!(s.contains("2.0 accel slots"), "{s}");
        // per-member preempt column + totals
        assert!(s.contains("preempt"), "{s}");
        // fungible pool: no node or zone lines
        assert!(!s.contains("pool nodes:"), "{s}");
        assert!(!s.contains("pool zones:"), "{s}");
        assert_eq!(s.lines().count(), 2 + 2 + 1 + 4);
    }

    #[test]
    fn fleet_table_prints_per_shape_node_counts() {
        use crate::metrics::RunMetrics;
        let pool = PoolReport {
            budget: 32,
            pool_min: 24,
            pool_max: 32,
            peak_in_use: 12,
            resizes: 1,
            migrations: 7,
            zone_kills: 1,
            preemptions: 0,
            preempted: vec![0],
            bought_replica_secs: 640.0,
            used_replica_secs: 320.0,
            nodes_final: vec![("(8c/32g/0a)".into(), 4), ("(16c/64g/2a)".into(), 2)],
            node_secs: vec![("(8c/32g/0a)".into(), 80.0), ("(16c/64g/2a)".into(), 40.0)],
            nodes_by_zone: vec![("east".into(), 4), ("west".into(), 2)],
        };
        let m = RunMetrics { pipeline: "video".into(), workload: "bursty".into(), ..Default::default() };
        let s = fleet_table(&["m0".to_string()], &[m], &[6], &pool);
        assert!(s.contains("pool nodes: 4x(8c/32g/0a) + 2x(16c/64g/2a)"), "{s}");
        assert!(
            s.contains("node-seconds bought per shape: (8c/32g/0a)=80, (16c/64g/2a)=40"),
            "{s}"
        );
        assert!(s.contains("replica migrations: 7 | zone kills: 1"), "{s}");
        assert!(s.contains("pool zones: east=4 nodes, west=2 nodes"), "{s}");
        // the node lines keep the column-aligned table intact above
        assert!(s.contains("TOTAL"), "{s}");
    }

    #[test]
    fn router_table_rows_and_disabled_notice() {
        use crate::metrics::RouterStats;
        let names = vec!["video-edge".to_string(), "nlp-batchline".to_string()];
        // No routing at all → one-line notice, no table.
        let off = router_table(&names, &[RouterStats::default(), RouterStats::default()]);
        assert!(off.contains("disabled (pre-addressed ingress)"), "{off}");
        assert_eq!(off.lines().count(), 1);
        // Routed run → header + 2 member rows + TOTAL.
        let a = RouterStats {
            routed: vec![30, 10, 10, 10],
            degraded: 5,
            shed: 2,
            cross_zone: 7,
            warm_hits: 11,
        };
        let b = RouterStats { routed: vec![20, 20], ..Default::default() };
        let s = router_table(&names, &[a, b]);
        assert!(s.contains("video-edge"), "{s}");
        assert!(s.contains("nlp-batchline"));
        // member a: 60 routed over 4 replicas, skew 100% (mean 15, max 30)
        assert!(s.contains("100.0%"), "{s}");
        assert!(s.contains("TOTAL"), "{s}");
        // totals: 100 routed, 5 degraded, 2 shed, 7 cross-zone, 11 warm
        let total_line = s.lines().last().unwrap();
        for v in ["100", "5", "2", "7", "11"] {
            assert!(total_line.split_whitespace().any(|c| c == v), "{total_line}");
        }
        assert_eq!(s.lines().count(), 2 + 2 + 1);
    }
}
