//! Per-trace waterfall rendering: turn a drained span dump into the
//! "where did this request's 310 ms go?" picture — one row per hop,
//! offset and scaled against the request's end-to-end window.

use crate::telemetry::{Hop, Span};
use std::fmt::Write as _;

/// Width of the bar area, characters.
const BAR_WIDTH: usize = 48;

/// Distinct trace ids present in a span dump, ascending.
pub fn trace_ids(spans: &[Span]) -> Vec<u64> {
    let mut ids: Vec<u64> = spans.iter().map(|s| s.trace).collect();
    ids.sort_unstable();
    ids.dedup();
    ids
}

/// Sum of the timed hops (queue-wait + exec) of one trace — by the DES
/// recording contract this telescopes exactly to the request's
/// end-to-end latency.
pub fn trace_span_sum(spans: &[Span], trace: u64) -> f64 {
    spans
        .iter()
        .filter(|s| s.trace == trace && matches!(s.hop, Hop::QueueWait | Hop::Exec))
        .map(|s| s.dur)
        .sum()
}

/// End-to-end latency a trace recorded on its terminal hop (`Done` or
/// `Drop`), if it has one.
pub fn trace_end_to_end(spans: &[Span], trace: u64) -> Option<f64> {
    spans
        .iter()
        .find(|s| s.trace == trace && matches!(s.hop, Hop::Done | Hop::Drop))
        .map(|s| s.dur)
}

/// Render one trace as an ASCII waterfall.  Rows are hops in time
/// order; each bar is positioned within the trace's [start, end]
/// window.  Empty string when the trace has no spans.
pub fn waterfall(spans: &[Span], trace: u64) -> String {
    let mut hops: Vec<&Span> = spans.iter().filter(|s| s.trace == trace).collect();
    if hops.is_empty() {
        return String::new();
    }
    hops.sort_by(|a, b| a.t.partial_cmp(&b.t).unwrap().then(a.hop.cmp(&b.hop)));
    let start = hops.iter().map(|s| s.t).fold(f64::INFINITY, f64::min);
    let end = hops.iter().map(|s| s.t + s.dur).fold(f64::NEG_INFINITY, f64::max);
    let window = (end - start).max(1e-9);
    let mut out = String::new();
    let _ = writeln!(
        out,
        "trace {trace}: {:.3}s end-to-end ({} hops, t={start:.3}..{end:.3})",
        end - start,
        hops.len()
    );
    for s in hops {
        let off = (((s.t - start) / window) * BAR_WIDTH as f64) as usize;
        let len = ((s.dur / window) * BAR_WIDTH as f64).ceil() as usize;
        let off = off.min(BAR_WIDTH - 1);
        let len = len.clamp(usize::from(s.dur > 0.0), BAR_WIDTH - off);
        let bar: String =
            " ".repeat(off) + &"#".repeat(len) + &" ".repeat(BAR_WIDTH - off - len);
        let _ = writeln!(
            out,
            "  m{:<2} s{:<2} {:<10} |{bar}| {:>9.3}ms",
            s.member,
            s.stage,
            s.hop.name(),
            s.dur * 1e3
        );
    }
    out
}

/// Waterfalls for the first `limit` traces of a dump.
pub fn waterfalls(spans: &[Span], limit: usize) -> String {
    let mut out = String::new();
    for id in trace_ids(spans).into_iter().take(limit) {
        out.push_str(&waterfall(spans, id));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn hop(trace: u64, stage: u32, hop: Hop, t: f64, dur: f64) -> Span {
        Span { trace, member: 0, stage, hop, t, dur, value: 0.0 }
    }

    #[test]
    fn waterfall_renders_all_hops_in_window() {
        let spans = vec![
            hop(3, 0, Hop::Arrival, 1.0, 0.0),
            hop(3, 0, Hop::QueueWait, 1.0, 0.2),
            hop(3, 0, Hop::Exec, 1.2, 0.3),
            hop(3, 1, Hop::QueueWait, 1.5, 0.1),
            hop(3, 1, Hop::Exec, 1.6, 0.4),
            hop(3, 1, Hop::Done, 2.0, 1.0),
        ];
        let w = waterfall(&spans, 3);
        assert!(w.starts_with("trace 3:"));
        assert_eq!(w.lines().count(), 7);
        assert!(w.contains("queue_wait"));
        assert!(w.contains("exec"));
        // the timed hops telescope to the end-to-end latency
        assert!((trace_span_sum(&spans, 3) - 1.0).abs() < 1e-12);
        assert_eq!(trace_end_to_end(&spans, 3), Some(1.0));
    }

    #[test]
    fn missing_trace_is_empty() {
        assert_eq!(waterfall(&[], 9), "");
        assert_eq!(trace_end_to_end(&[], 9), None);
    }

    #[test]
    fn trace_ids_sorted_unique() {
        let spans = vec![
            hop(5, 0, Hop::Done, 0.0, 0.1),
            hop(2, 0, Hop::Done, 0.0, 0.1),
            hop(5, 0, Hop::Arrival, 0.0, 0.0),
        ];
        assert_eq!(trace_ids(&spans), vec![2, 5]);
    }
}
