//! Statistics helpers: summary stats, percentiles, CDFs, SMAPE, and the
//! least-squares quadratic fit used by the §4.2 latency profiler.

/// Mean of a slice (0.0 for empty input).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Population standard deviation.
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// Percentile (linear interpolation), `p` in [0,100].  Non-finite
/// samples (NaN/±inf) are ignored rather than poisoning the sort.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    let mut v: Vec<f64> = xs.iter().copied().filter(|x| x.is_finite()).collect();
    if v.is_empty() {
        return 0.0;
    }
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    percentile_sorted(&v, p)
}

/// Percentile over an already-sorted slice of finite values.
pub fn percentile_sorted(v: &[f64], p: f64) -> f64 {
    if v.is_empty() {
        return 0.0;
    }
    let rank = (p / 100.0) * (v.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        let w = rank - lo as f64;
        v[lo] * (1.0 - w) + v[hi] * w
    }
}

/// Symmetric mean absolute percentage error, in percent (paper §5.1
/// reports the LSTM at 6.6% SMAPE).
pub fn smape(pred: &[f64], truth: &[f64]) -> f64 {
    assert_eq!(pred.len(), truth.len());
    if pred.is_empty() {
        return 0.0;
    }
    let mut acc = 0.0;
    for (p, t) in pred.iter().zip(truth) {
        let denom = ((p.abs() + t.abs()) / 2.0).max(1e-9);
        acc += (p - t).abs() / denom;
    }
    acc / pred.len() as f64 * 100.0
}

/// Summary stats bundle for reporting.
#[derive(Debug, Clone, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub p50: f64,
    pub p95: f64,
    pub p99: f64,
    pub max: f64,
}

impl Summary {
    pub fn of(xs: &[f64]) -> Summary {
        // Non-finite samples are dropped (they would poison the sort
        // and every moment).
        let mut v: Vec<f64> = xs.iter().copied().filter(|x| x.is_finite()).collect();
        if v.is_empty() {
            return Summary {
                n: 0,
                mean: 0.0,
                std: 0.0,
                min: 0.0,
                p50: 0.0,
                p95: 0.0,
                p99: 0.0,
                max: 0.0,
            };
        }
        v.sort_by(|a, b| a.partial_cmp(b).unwrap());
        Summary {
            n: v.len(),
            mean: mean(&v),
            std: stddev(&v),
            min: v[0],
            p50: percentile_sorted(&v, 50.0),
            p95: percentile_sorted(&v, 95.0),
            p99: percentile_sorted(&v, 99.0),
            max: *v.last().unwrap(),
        }
    }
}

/// Empirical CDF: returns (sorted values, cumulative fraction) pairs,
/// downsampled to at most `points` entries — used for Fig. 15.
pub fn cdf(xs: &[f64], points: usize) -> Vec<(f64, f64)> {
    if xs.is_empty() {
        return Vec::new();
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = v.len();
    let step = (n / points.max(1)).max(1);
    let mut out = Vec::new();
    let mut i = 0;
    while i < n {
        out.push((v[i], (i + 1) as f64 / n as f64));
        i += step;
    }
    if out.last().map(|&(x, _)| x) != Some(v[n - 1]) {
        out.push((v[n - 1], 1.0));
    }
    out
}

/// Least-squares fit of `y = a·x² + b·x + c` (the paper's §4.2 latency
/// model `l(b) = αb² + βb + γ`).  Returns `[a, b, c]`.
///
/// Solves the 3×3 normal equations with Gaussian elimination + partial
/// pivoting; needs ≥3 distinct x values.
pub fn quadratic_fit(xs: &[f64], ys: &[f64]) -> Option<[f64; 3]> {
    assert_eq!(xs.len(), ys.len());
    if xs.len() < 3 {
        return None;
    }
    // Normal matrix for basis [x², x, 1].
    let (mut s4, mut s3, mut s2, mut s1) = (0.0, 0.0, 0.0, 0.0);
    let (mut t2, mut t1, mut t0) = (0.0, 0.0, 0.0);
    let n = xs.len() as f64;
    for (&x, &y) in xs.iter().zip(ys) {
        let x2 = x * x;
        s4 += x2 * x2;
        s3 += x2 * x;
        s2 += x2;
        s1 += x;
        t2 += x2 * y;
        t1 += x * y;
        t0 += y;
    }
    let mut m = [
        [s4, s3, s2, t2],
        [s3, s2, s1, t1],
        [s2, s1, n, t0],
    ];
    // Gaussian elimination with partial pivoting.
    for col in 0..3 {
        let piv = (col..3).max_by(|&a, &b| m[a][col].abs().partial_cmp(&m[b][col].abs()).unwrap())?;
        if m[piv][col].abs() < 1e-12 {
            return None;
        }
        m.swap(col, piv);
        for row in 0..3 {
            if row != col {
                let f = m[row][col] / m[col][col];
                for k in col..4 {
                    m[row][k] -= f * m[col][k];
                }
            }
        }
    }
    Some([m[0][3] / m[0][0], m[1][3] / m[1][1], m[2][3] / m[2][2]])
}

/// Mean squared error of a quadratic fit (for the §4.2 claim that the
/// quadratic beats the linear fit).
pub fn fit_mse(coef: &[f64; 3], xs: &[f64], ys: &[f64]) -> f64 {
    let errs: Vec<f64> = xs
        .iter()
        .zip(ys)
        .map(|(&x, &y)| {
            let p = coef[0] * x * x + coef[1] * x + coef[2];
            (p - y) * (p - y)
        })
        .collect();
    mean(&errs)
}

/// Least-squares line fit `y = b·x + c`; returns `[b, c]`.
pub fn linear_fit(xs: &[f64], ys: &[f64]) -> Option<[f64; 2]> {
    if xs.len() < 2 {
        return None;
    }
    let n = xs.len() as f64;
    let sx: f64 = xs.iter().sum();
    let sy: f64 = ys.iter().sum();
    let sxx: f64 = xs.iter().map(|x| x * x).sum();
    let sxy: f64 = xs.iter().zip(ys).map(|(x, y)| x * y).sum();
    let denom = n * sxx - sx * sx;
    if denom.abs() < 1e-12 {
        return None;
    }
    let b = (n * sxy - sx * sy) / denom;
    let c = (sy - b * sx) / n;
    Some([b, c])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_std() {
        assert_eq!(mean(&[1.0, 2.0, 3.0]), 2.0);
        assert!(stddev(&[2.0, 2.0, 2.0]) < 1e-12);
        assert!((stddev(&[1.0, 3.0]) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn percentiles() {
        let v: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        assert!((percentile(&v, 50.0) - 50.5).abs() < 1e-9);
        assert_eq!(percentile(&v, 0.0), 1.0);
        assert_eq!(percentile(&v, 100.0), 100.0);
        assert!((percentile(&v, 99.0) - 99.01).abs() < 0.02);
    }

    #[test]
    fn smape_basics() {
        assert_eq!(smape(&[1.0, 2.0], &[1.0, 2.0]), 0.0);
        let s = smape(&[11.0], &[10.0]);
        assert!((s - 100.0 / 10.5).abs() < 1e-9);
    }

    #[test]
    fn quad_fit_exact() {
        let xs: Vec<f64> = vec![1.0, 2.0, 4.0, 8.0, 16.0, 32.0, 64.0];
        let ys: Vec<f64> = xs.iter().map(|x| 0.003 * x * x + 1.2 * x + 0.7).collect();
        let c = quadratic_fit(&xs, &ys).unwrap();
        assert!((c[0] - 0.003).abs() < 1e-9, "{c:?}");
        assert!((c[1] - 1.2).abs() < 1e-7, "{c:?}");
        assert!((c[2] - 0.7).abs() < 1e-6, "{c:?}");
        assert!(fit_mse(&c, &xs, &ys) < 1e-12);
    }

    #[test]
    fn quad_beats_linear_on_curved_data() {
        // The §4.2 claim: quadratic fit has lower MSE than linear on
        // batch-latency curves.
        let xs: Vec<f64> = vec![1.0, 2.0, 4.0, 8.0, 16.0, 32.0, 64.0];
        let ys: Vec<f64> = xs.iter().map(|x| 0.01 * x * x + 0.8 * x + 2.0).collect();
        let q = quadratic_fit(&xs, &ys).unwrap();
        let l = linear_fit(&xs, &ys).unwrap();
        let lin_mse = mean(
            &xs.iter()
                .zip(&ys)
                .map(|(&x, &y)| {
                    let p = l[0] * x + l[1];
                    (p - y) * (p - y)
                })
                .collect::<Vec<_>>(),
        );
        assert!(fit_mse(&q, &xs, &ys) < lin_mse);
    }

    #[test]
    fn quad_fit_degenerate() {
        assert!(quadratic_fit(&[1.0, 1.0, 1.0], &[1.0, 1.0, 1.0]).is_none());
        assert!(quadratic_fit(&[1.0, 2.0], &[1.0, 2.0]).is_none());
    }

    #[test]
    fn cdf_shape() {
        let xs: Vec<f64> = (0..1000).map(|i| i as f64).collect();
        let c = cdf(&xs, 50);
        assert!(c.len() <= 52);
        assert_eq!(c.last().unwrap().1, 1.0);
        for w in c.windows(2) {
            assert!(w[0].0 <= w[1].0);
            assert!(w[0].1 <= w[1].1);
        }
    }

    #[test]
    fn summary_of() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(s.n, 4);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 4.0);
        assert!((s.p50 - 2.5).abs() < 1e-9);
    }

    #[test]
    fn percentile_edge_cases() {
        // empty
        assert_eq!(percentile(&[], 50.0), 0.0);
        assert_eq!(percentile_sorted(&[], 50.0), 0.0);
        // single element: every percentile is that element
        assert_eq!(percentile(&[7.5], 0.0), 7.5);
        assert_eq!(percentile(&[7.5], 50.0), 7.5);
        assert_eq!(percentile(&[7.5], 100.0), 7.5);
        assert_eq!(percentile_sorted(&[7.5], 99.0), 7.5);
    }

    #[test]
    fn percentile_ignores_non_finite() {
        // NaN/inf samples are dropped, not propagated
        assert_eq!(percentile(&[f64::NAN], 50.0), 0.0);
        assert_eq!(percentile(&[1.0, f64::NAN, 3.0], 50.0), 2.0);
        assert_eq!(percentile(&[f64::INFINITY, 2.0, f64::NEG_INFINITY], 50.0), 2.0);
    }

    #[test]
    fn summary_of_edge_cases() {
        let empty = Summary::of(&[]);
        assert_eq!(empty.n, 0);
        assert_eq!(empty.mean, 0.0);
        assert_eq!(empty.min, 0.0);
        assert_eq!(empty.max, 0.0);
        // single element
        let one = Summary::of(&[3.0]);
        assert_eq!(one.n, 1);
        assert_eq!(one.std, 0.0);
        assert_eq!(one.p99, 3.0);
        // NaN-containing input reduces to the finite subset
        let s = Summary::of(&[1.0, f64::NAN, 3.0]);
        assert_eq!(s.n, 2);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 3.0);
        assert!((s.mean - 2.0).abs() < 1e-12);
        // all-NaN behaves like empty
        assert_eq!(Summary::of(&[f64::NAN, f64::NAN]).n, 0);
    }
}
