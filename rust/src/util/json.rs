//! Minimal JSON — parser + serializer (serde substitute; the offline
//! registry has no serde).  Covers everything this crate needs: the AOT
//! manifest, config files, and result dumps.  Numbers are f64 (with i64
//! accessors), strings support the standard escapes incl. \uXXXX.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    // ---- accessors -------------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// `obj["a"]["b"][2]`-style path access; indices as "0", "1", ...
    pub fn path(&self, path: &[&str]) -> Option<&Json> {
        let mut cur = self;
        for p in path {
            cur = match cur {
                Json::Obj(m) => m.get(*p)?,
                Json::Arr(a) => a.get(p.parse::<usize>().ok()?)?,
                _ => return None,
            };
        }
        Some(cur)
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|f| f as i64)
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|f| f as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    // ---- builders --------------------------------------------------------

    pub fn obj() -> Json {
        Json::Obj(BTreeMap::new())
    }

    pub fn set(mut self, key: &str, v: impl Into<Json>) -> Json {
        if let Json::Obj(ref mut m) = self {
            m.insert(key.to_string(), v.into());
        }
        self
    }
}

impl From<f64> for Json {
    fn from(v: f64) -> Json {
        Json::Num(v)
    }
}
impl From<i64> for Json {
    fn from(v: i64) -> Json {
        Json::Num(v as f64)
    }
}
impl From<usize> for Json {
    fn from(v: usize) -> Json {
        Json::Num(v as f64)
    }
}
impl From<bool> for Json {
    fn from(v: bool) -> Json {
        Json::Bool(v)
    }
}
impl From<&str> for Json {
    fn from(v: &str) -> Json {
        Json::Str(v.to_string())
    }
}
impl From<String> for Json {
    fn from(v: String) -> Json {
        Json::Str(v)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(v: Vec<T>) -> Json {
        Json::Arr(v.into_iter().map(Into::into).collect())
    }
}

/// Parse error with byte offset.
#[derive(Debug, Clone, PartialEq)]
pub struct JsonError {
    pub offset: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.offset, self.msg)
    }
}

impl std::error::Error for JsonError {}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { offset: self.i, msg: msg.to_string() }
    }

    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{s}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut a = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(a));
        }
        loop {
            a.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(a));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex = std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            s.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // consume one UTF-8 scalar
                    let start = self.i;
                    let rest = std::str::from_utf8(&self.b[start..])
                        .map_err(|_| self.err("invalid utf8"))?;
                    let ch = rest.chars().next().unwrap();
                    s.push(ch);
                    self.i += ch.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self
            .peek()
            .is_some_and(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.i += 1;
        }
        let txt = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        txt.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

// ---- serialization ---------------------------------------------------------

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Json::Str(s) => write_escaped(f, s),
            Json::Arr(a) => {
                write!(f, "[")?;
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{v}")?;
                }
                write!(f, "]")
            }
            Json::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write_escaped(f, k)?;
                    write!(f, ":{v}")?;
                }
                write!(f, "}}")
            }
        }
    }
}

fn write_escaped(f: &mut fmt::Formatter<'_>, s: &str) -> fmt::Result {
    write!(f, "\"")?;
    for c in s.chars() {
        match c {
            '"' => write!(f, "\\\"")?,
            '\\' => write!(f, "\\\\")?,
            '\n' => write!(f, "\\n")?,
            '\t' => write!(f, "\\t")?,
            '\r' => write!(f, "\\r")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    write!(f, "\"")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse(" false ").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("3.5").unwrap(), Json::Num(3.5));
        assert_eq!(Json::parse("-12e2").unwrap(), Json::Num(-1200.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": "c"}], "d": null}"#).unwrap();
        assert_eq!(j.path(&["a", "2", "b"]).unwrap().as_str(), Some("c"));
        assert_eq!(j.get("d"), Some(&Json::Null));
        assert_eq!(j.path(&["a", "0"]).unwrap().as_i64(), Some(1));
    }

    #[test]
    fn parse_escapes() {
        let j = Json::parse(r#""a\nb\t\"q\" A""#).unwrap();
        assert_eq!(j.as_str(), Some("a\nb\t\"q\" A"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("nul").is_err());
        assert!(Json::parse("\"abc").is_err());
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"arr":[1,2.5,true,null,"s\"x"],"n":-3,"o":{"k":1}}"#;
        let j = Json::parse(src).unwrap();
        let out = j.to_string();
        assert_eq!(Json::parse(&out).unwrap(), j);
    }

    #[test]
    fn builder() {
        let j = Json::obj().set("a", 1i64).set("b", "x").set("c", vec![1i64, 2]);
        assert_eq!(j.get("a").unwrap().as_i64(), Some(1));
        assert_eq!(j.get("c").unwrap().as_arr().unwrap().len(), 2);
    }

    #[test]
    fn unicode_passthrough() {
        let j = Json::parse("\"héllo ☃\"").unwrap();
        assert_eq!(j.as_str(), Some("héllo ☃"));
        assert_eq!(Json::parse(&j.to_string()).unwrap(), j);
    }
}
