//! Tiny CLI argument parser (clap substitute).
//!
//! Grammar: `ipa <subcommand> [--key value]... [--flag]... [positional]...`
//! Unknown keys are kept and can be validated by the caller.

use std::collections::BTreeMap;

/// Parsed command line.
#[derive(Debug, Clone, Default)]
pub struct Args {
    /// First non-flag token (the subcommand), if any.
    pub command: Option<String>,
    /// `--key value` pairs; bare `--flag` maps to "true".
    pub opts: BTreeMap<String, String>,
    /// Remaining positional arguments after the subcommand.
    pub positional: Vec<String>,
}

impl Args {
    /// Parse from an iterator of tokens (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(items: I) -> Args {
        let mut out = Args::default();
        let mut it = items.into_iter().peekable();
        while let Some(tok) = it.next() {
            if let Some(key) = tok.strip_prefix("--") {
                // --key=value or --key value or bare --flag
                if let Some((k, v)) = key.split_once('=') {
                    out.opts.insert(k.to_string(), v.to_string());
                } else if it.peek().is_some_and(|n| !n.starts_with("--")) {
                    out.opts.insert(key.to_string(), it.next().unwrap());
                } else {
                    out.opts.insert(key.to_string(), "true".to_string());
                }
            } else if out.command.is_none() {
                out.command = Some(tok);
            } else {
                out.positional.push(tok);
            }
        }
        out
    }

    /// Parse the process arguments.
    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.opts.get(key).map(String::as_str)
    }

    pub fn get_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    pub fn get_f64(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn get_usize(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn get_u64(&self, key: &str, default: u64) -> u64 {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn flag(&self, key: &str) -> bool {
        matches!(self.get(key), Some("true") | Some("1") | Some("yes"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn subcommand_and_opts() {
        let a = parse("simulate --pipeline video --seconds 600 --verbose");
        assert_eq!(a.command.as_deref(), Some("simulate"));
        assert_eq!(a.get("pipeline"), Some("video"));
        assert_eq!(a.get_usize("seconds", 0), 600);
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
    }

    #[test]
    fn eq_form_and_positionals() {
        let a = parse("report fig8 --alpha=2.5 extra");
        assert_eq!(a.command.as_deref(), Some("report"));
        assert_eq!(a.positional, vec!["fig8", "extra"]);
        assert_eq!(a.get_f64("alpha", 0.0), 2.5);
    }

    #[test]
    fn flag_before_value_opt() {
        let a = parse("x --dry-run --out file.txt");
        assert!(a.flag("dry-run"));
        assert_eq!(a.get("out"), Some("file.txt"));
    }

    #[test]
    fn defaults() {
        let a = parse("run");
        assert_eq!(a.get_or("mode", "sim"), "sim");
        assert_eq!(a.get_f64("beta", 1.5), 1.5);
    }
}
