//! Minimal error type (anyhow substitute — the offline build has no
//! external crates).
//!
//! Provides the small surface the runtime and serving layers use:
//! a string-backed [`Error`], a defaulted [`Result`] alias, the
//! [`Context`] extension trait (`.context(..)` / `.with_context(..)`)
//! and the crate-level `anyhow!` macro for formatted ad-hoc errors.

use std::fmt;

/// A string-backed error with prepended context, `anyhow::Error`-shaped.
#[derive(Clone, PartialEq, Eq)]
pub struct Error {
    msg: String,
}

impl Error {
    pub fn msg(msg: impl Into<String>) -> Error {
        Error { msg: msg.into() }
    }

    /// Prepend a context layer (outermost first, like anyhow's chain).
    pub fn wrap(self, context: impl fmt::Display) -> Error {
        Error { msg: format!("{context}: {}", self.msg) }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        // `{e}` and `{e:#}` both print the full chain.
        write!(f, "{}", self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)
    }
}

impl std::error::Error for Error {}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Error {
        Error::msg(e.to_string())
    }
}

impl From<String> for Error {
    fn from(msg: String) -> Error {
        Error::msg(msg)
    }
}

impl From<&str> for Error {
    fn from(msg: &str) -> Error {
        Error::msg(msg)
    }
}

/// Crate-wide result alias (anyhow::Result substitute).
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Context extension for results and options.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{context}: {e}")))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{}: {e}", f())))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context.to_string()))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f().to_string()))
    }
}

/// Formatted ad-hoc error (anyhow::anyhow! substitute).
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::util::error::Error::msg(format!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fails() -> Result<u32> {
        Err(crate::anyhow!("bad value {}", 7))
    }

    #[test]
    fn macro_formats() {
        let e = fails().unwrap_err();
        assert_eq!(e.to_string(), "bad value 7");
        assert_eq!(format!("{e:#}"), "bad value 7");
    }

    #[test]
    fn context_chains_outermost_first() {
        let r: Result<u32> = fails().with_context(|| "loading config".to_string());
        assert_eq!(r.unwrap_err().to_string(), "loading config: bad value 7");
        let r2: Result<u32> = fails().context("outer");
        assert_eq!(r2.unwrap_err().to_string(), "outer: bad value 7");
    }

    #[test]
    fn option_context() {
        let none: Option<u32> = None;
        assert!(none.context("missing").is_err());
        assert_eq!(Some(3u32).context("missing").unwrap(), 3);
    }

    #[test]
    fn io_error_converts() {
        let e: Error = std::io::Error::new(std::io::ErrorKind::NotFound, "gone").into();
        assert!(e.to_string().contains("gone"));
    }
}
