//! Minimal property-based testing harness (proptest substitute).
//!
//! A property is a closure over a [`Gen`]; the runner executes it for
//! `cases` seeded generations and, on failure, retries the failing seed
//! with progressively smaller `size` to report a smaller counterexample.
//!
//! ```ignore
//! check("sort is idempotent", 200, |g| {
//!     let v = g.vec_f64(0.0, 100.0, 64);
//!     let mut a = v.clone(); a.sort_by(|x, y| x.partial_cmp(y).unwrap());
//!     let mut b = a.clone(); b.sort_by(|x, y| x.partial_cmp(y).unwrap());
//!     prop_assert(a == b, "double sort differs")
//! });
//! ```

use super::rng::SplitMix64;

/// Value generator handed to properties.
pub struct Gen {
    rng: SplitMix64,
    /// Soft size bound: collections/magnitudes scale with this.
    pub size: usize,
    pub seed: u64,
}

impl Gen {
    pub fn new(seed: u64, size: usize) -> Gen {
        Gen { rng: SplitMix64::new(seed), size, seed }
    }

    pub fn u64(&mut self, lo: u64, hi: u64) -> u64 {
        if hi <= lo {
            return lo;
        }
        self.rng.range_u64(lo, hi)
    }

    pub fn usize(&mut self, lo: usize, hi: usize) -> usize {
        self.u64(lo as u64, hi as u64) as usize
    }

    pub fn f64(&mut self, lo: f64, hi: f64) -> f64 {
        self.rng.range_f64(lo, hi)
    }

    pub fn bool(&mut self) -> bool {
        self.rng.next_u64() & 1 == 1
    }

    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.rng.index(xs.len())]
    }

    /// Vector with length in [1, max_len.min(size)].
    pub fn vec_f64(&mut self, lo: f64, hi: f64, max_len: usize) -> Vec<f64> {
        let cap = max_len.min(self.size).max(1);
        let n = self.usize(1, cap + 1);
        (0..n).map(|_| self.f64(lo, hi)).collect()
    }

    pub fn vec_usize(&mut self, lo: usize, hi: usize, max_len: usize) -> Vec<usize> {
        let cap = max_len.min(self.size).max(1);
        let n = self.usize(1, cap + 1);
        (0..n).map(|_| self.usize(lo, hi)).collect()
    }

    /// Power of two in [1, max_pow2] (batch sizes).
    pub fn pow2(&mut self, max_pow2: u32) -> usize {
        1usize << self.u64(0, max_pow2 as u64 + 1)
    }
}

/// Property outcome.
pub type PropResult = Result<(), String>;

/// Assert inside a property.
pub fn prop_assert(cond: bool, msg: &str) -> PropResult {
    if cond {
        Ok(())
    } else {
        Err(msg.to_string())
    }
}

/// Assert `|a-b| <= tol` inside a property.
pub fn prop_close(a: f64, b: f64, tol: f64, msg: &str) -> PropResult {
    if (a - b).abs() <= tol {
        Ok(())
    } else {
        Err(format!("{msg}: {a} vs {b} (tol {tol})"))
    }
}

/// Run `prop` for `cases` generated inputs; panics with the seed and the
/// smallest failing size on failure (rerun with `Gen::new(seed, size)` to
/// reproduce deterministically).
pub fn check<F>(name: &str, cases: u64, prop: F)
where
    F: Fn(&mut Gen) -> PropResult,
{
    let base = crate::util::rng::fnv1a64(name);
    for case in 0..cases {
        let seed = base ^ (case.wrapping_mul(0x9E37_79B9_7F4A_7C15));
        let size = 4 + (case as usize % 64);
        let mut g = Gen::new(seed, size);
        if let Err(msg) = prop(&mut g) {
            // "Shrink": find the smallest size at which this seed fails.
            let mut min_size = size;
            let mut min_msg = msg;
            let mut s = 1;
            while s < size {
                let mut g2 = Gen::new(seed, s);
                if let Err(m2) = prop(&mut g2) {
                    min_size = s;
                    min_msg = m2;
                    break;
                }
                s *= 2;
            }
            panic!(
                "property '{name}' failed (case {case}, seed {seed:#x}, size {min_size}): {min_msg}"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property() {
        check("add commutes", 100, |g| {
            let a = g.f64(-1e6, 1e6);
            let b = g.f64(-1e6, 1e6);
            prop_close(a + b, b + a, 0.0, "commutativity")
        });
    }

    #[test]
    #[should_panic(expected = "property 'always fails'")]
    fn failing_property_panics_with_seed() {
        check("always fails", 5, |g| {
            let _ = g.u64(0, 10);
            prop_assert(false, "nope")
        });
    }

    #[test]
    fn deterministic_given_seed() {
        let mut g1 = Gen::new(123, 16);
        let mut g2 = Gen::new(123, 16);
        for _ in 0..100 {
            assert_eq!(g1.u64(0, 1000), g2.u64(0, 1000));
        }
    }

    #[test]
    fn pow2_is_power_of_two() {
        check("pow2", 200, |g| {
            let b = g.pow2(6);
            prop_assert(b.is_power_of_two() && b <= 64, "pow2 range")
        });
    }

    #[test]
    fn vec_len_respects_size() {
        check("vec len", 100, |g| {
            let v = g.vec_f64(0.0, 1.0, 1000);
            prop_assert(!v.is_empty() && v.len() <= g.size.max(1), "len")
        });
    }
}
