//! Deterministic PRNGs.
//!
//! [`SplitMix64`] is the workhorse and is a bit-exact twin of
//! `python/compile/tracegen.py::SplitMix64` / `model.splitmix64_fill` —
//! the workload generator and the artifact weight generator on both
//! sides of the language boundary must produce identical streams so the
//! AOT check values and the LSTM training distribution line up.

/// SplitMix64 (Steele et al.) — tiny, fast, full 64-bit period splitter.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }

    /// Uniform in `[0, 1)` from the top 53 bits — bit-exact with the
    /// python twin (`(u >> 11) / 2**53`).
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Uniform in `[lo, hi)`.
    #[inline]
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.next_f64()
    }

    /// Uniform integer in `[lo, hi)` (hi > lo).
    #[inline]
    pub fn range_u64(&mut self, lo: u64, hi: u64) -> u64 {
        lo + self.next_u64() % (hi - lo)
    }

    /// Uniform usize in `[0, n)`.
    #[inline]
    pub fn index(&mut self, n: usize) -> usize {
        (self.next_u64() % n as u64) as usize
    }

    /// `f32` in `[-0.5, 0.5)` from the top 24 bits — twin of
    /// `model.splitmix64_fill` (used for artifact weights).
    #[inline]
    pub fn next_f32_centered(&mut self) -> f32 {
        let z = self.next_u64();
        ((z >> 40) as f32 / (1u32 << 24) as f32) - 0.5
    }

    /// Standard normal via Box–Muller (rust-only consumers: latency
    /// noise, load jitter — never crosses the language boundary).
    pub fn next_normal(&mut self) -> f64 {
        let u1 = self.next_f64().max(1e-12);
        let u2 = self.next_f64();
        (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
    }

    /// Poisson sample via Knuth's method (λ < ~30 in our traces) with a
    /// normal approximation fallback for large λ.
    pub fn next_poisson(&mut self, lambda: f64) -> u64 {
        if lambda <= 0.0 {
            return 0;
        }
        if lambda > 30.0 {
            let v = lambda + lambda.sqrt() * self.next_normal();
            return v.max(0.0).round() as u64;
        }
        let l = (-lambda).exp();
        let mut k = 0u64;
        let mut p = 1.0;
        loop {
            p *= self.next_f64();
            if p <= l {
                return k;
            }
            k += 1;
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.index(i + 1);
            xs.swap(i, j);
        }
    }
}

/// FNV-1a 64-bit hash — twin of `python/compile/model.fnv1a64`, used to
/// derive per-variant weight seeds from the variant key string.
pub fn fnv1a64(s: &str) -> u64 {
    let mut h: u64 = 0xCBF2_9CE4_8422_2325;
    for &b in s.as_bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01B3);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_known_stream() {
        // First outputs for seed 0 (cross-checked against the python twin).
        let mut r = SplitMix64::new(0);
        let a = r.next_u64();
        let b = r.next_u64();
        assert_ne!(a, b);
        let mut r2 = SplitMix64::new(0);
        assert_eq!(a, r2.next_u64());
        assert_eq!(b, r2.next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = SplitMix64::new(42);
        for _ in 0..10_000 {
            let v = r.next_f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn range_f64_bounds() {
        let mut r = SplitMix64::new(7);
        for _ in 0..1000 {
            let v = r.range_f64(-3.0, 9.0);
            assert!((-3.0..9.0).contains(&v));
        }
    }

    #[test]
    fn f32_centered_bounds() {
        let mut r = SplitMix64::new(99);
        for _ in 0..10_000 {
            let v = r.next_f32_centered();
            assert!((-0.5..0.5).contains(&v));
        }
    }

    #[test]
    fn poisson_mean_close() {
        let mut r = SplitMix64::new(5);
        let n = 20_000;
        let lambda = 7.5;
        let sum: u64 = (0..n).map(|_| r.next_poisson(lambda)).sum();
        let mean = sum as f64 / n as f64;
        assert!((mean - lambda).abs() < 0.15, "mean {mean}");
    }

    #[test]
    fn poisson_large_lambda_normal_path() {
        let mut r = SplitMix64::new(6);
        let n = 20_000;
        let lambda = 120.0;
        let sum: u64 = (0..n).map(|_| r.next_poisson(lambda)).sum();
        let mean = sum as f64 / n as f64;
        assert!((mean - lambda).abs() < 1.0, "mean {mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = SplitMix64::new(11);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.next_normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn fnv_matches_python_twin() {
        // python: fnv1a64("detect.yolov5n") -> computed value pinned here;
        // the integration test re-derives it through the manifest checks.
        assert_eq!(fnv1a64(""), 0xCBF2_9CE4_8422_2325);
        assert_ne!(fnv1a64("a"), fnv1a64("b"));
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = SplitMix64::new(3);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut s = v.clone();
        s.sort_unstable();
        assert_eq!(s, (0..100).collect::<Vec<_>>());
    }
}
