//! Leveled stderr logging, controlled by the `IPA_LOG` env var
//! (`error|warn|info|debug|trace`, default `info`).  Unknown values
//! fall back to `info` with a one-time warning.  Each line carries the
//! last decision-journal sequence stamp (see
//! [`crate::telemetry::journal::Journal`]) so logs and journal entries
//! interleave consistently.

use std::sync::atomic::{AtomicBool, AtomicU64, AtomicU8, Ordering};
use std::time::{SystemTime, UNIX_EPOCH};

#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
#[repr(u8)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
    Trace = 4,
}

static LEVEL: AtomicU8 = AtomicU8::new(255); // 255 = uninitialized
static BAD_LEVEL_WARNED: AtomicBool = AtomicBool::new(false);

/// Last decision-journal sequence stamp, published by
/// `telemetry::journal::Journal::record` and printed (read-only) on
/// every log line: a line tagged `#n` happened after journal entry
/// `n - 1` and before entry `n`.  Logging never advances the counter,
/// so emitting logs cannot perturb journal determinism.
static JOURNAL_SEQ: AtomicU64 = AtomicU64::new(0);

/// Publish the journal's next sequence number (journal-side only).
pub fn note_journal_seq(seq: u64) {
    JOURNAL_SEQ.store(seq, Ordering::Relaxed);
}

/// The journal seq the next log line will be stamped with.
pub fn journal_seq() -> u64 {
    JOURNAL_SEQ.load(Ordering::Relaxed)
}

fn init_level() -> u8 {
    let lvl = match std::env::var("IPA_LOG").as_deref() {
        Ok("error") => Level::Error,
        Ok("warn") => Level::Warn,
        Ok("info") => Level::Info,
        Ok("debug") => Level::Debug,
        Ok("trace") => Level::Trace,
        Ok(other) => {
            if !BAD_LEVEL_WARNED.swap(true, Ordering::Relaxed) {
                eprintln!(
                    "[WARN  ipa::log] unknown IPA_LOG value {other:?}; accepted: \
                     error|warn|info|debug|trace (falling back to info)"
                );
            }
            Level::Info
        }
        Err(_) => Level::Info,
    } as u8;
    LEVEL.store(lvl, Ordering::Relaxed);
    lvl
}

/// Current log level.
pub fn level() -> Level {
    let raw = LEVEL.load(Ordering::Relaxed);
    let raw = if raw == 255 { init_level() } else { raw };
    match raw {
        0 => Level::Error,
        1 => Level::Warn,
        2 => Level::Info,
        3 => Level::Debug,
        _ => Level::Trace,
    }
}

/// Override the level programmatically (tests, benches).
pub fn set_level(l: Level) {
    LEVEL.store(l as u8, Ordering::Relaxed);
}

pub fn enabled(l: Level) -> bool {
    l <= level()
}

/// Log a message at the given level (used via the macros below).
pub fn log(l: Level, target: &str, msg: std::fmt::Arguments<'_>) {
    if !enabled(l) {
        return;
    }
    let t = SystemTime::now()
        .duration_since(UNIX_EPOCH)
        .map(|d| d.as_secs_f64())
        .unwrap_or(0.0);
    let tag = match l {
        Level::Error => "ERROR",
        Level::Warn => "WARN ",
        Level::Info => "INFO ",
        Level::Debug => "DEBUG",
        Level::Trace => "TRACE",
    };
    // `#n`: this line follows decision-journal entry n-1 (0 = before
    // any journal entry) — lets operators interleave logs and journal.
    let seq = journal_seq();
    eprintln!("[{t:.3} #{seq} {tag} {target}] {msg}");
}

#[macro_export]
macro_rules! log_error {
    ($target:expr, $($arg:tt)*) => {
        $crate::util::log::log($crate::util::log::Level::Error, $target, format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_warn {
    ($target:expr, $($arg:tt)*) => {
        $crate::util::log::log($crate::util::log::Level::Warn, $target, format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_info {
    ($target:expr, $($arg:tt)*) => {
        $crate::util::log::log($crate::util::log::Level::Info, $target, format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_debug {
    ($target:expr, $($arg:tt)*) => {
        $crate::util::log::log($crate::util::log::Level::Debug, $target, format_args!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn levels_ordered() {
        assert!(Level::Error < Level::Warn);
        assert!(Level::Warn < Level::Info);
        assert!(Level::Info < Level::Debug);
        assert!(Level::Debug < Level::Trace);
    }

    #[test]
    fn journal_seq_roundtrip() {
        note_journal_seq(41);
        assert_eq!(journal_seq(), 41);
        note_journal_seq(0);
    }

    #[test]
    fn set_and_check() {
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        set_level(Level::Info);
    }
}
