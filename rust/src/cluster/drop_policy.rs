//! §4.5 request dropping — the single home of the drop rule shared by
//! every driver.
//!
//! A request is dropped at batch-formation time when its end-to-end age
//! exceeds the SLA at a non-entry stage (it can no longer finish the
//! remaining stages in time), or exceeds 2×SLA anywhere (hard ceiling:
//! even entry-stage stragglers are shed rather than served uselessly).

use crate::queueing::Request;

/// The §4.5 drop rule.
#[derive(Debug, Clone, Copy)]
pub struct DropPolicy {
    /// End-to-end SLA, seconds.  This is the TRUE SLA — it also feeds
    /// the accounting/metrics, so SLA attainment is always judged
    /// against it regardless of `scale`.
    pub sla: f64,
    /// Disabled → nothing is ever dropped (ablation mode).
    pub enabled: bool,
    /// Drop-threshold multiplier (SLA-class policy): ages are judged
    /// against `scale × sla`, the reported SLA stays `sla`.  1.0 = the
    /// verbatim §4.5 rule.
    pub scale: f64,
}

impl DropPolicy {
    pub fn new(sla: f64, enabled: bool) -> Self {
        DropPolicy { sla, enabled, scale: 1.0 }
    }

    /// This policy with a drop-threshold multiplier (throughput-class
    /// members shed at `scale ×` the SLA while their attainment metric
    /// keeps the true SLA).
    pub fn scaled(mut self, scale: f64) -> Self {
        self.scale = scale;
        self
    }

    /// The age threshold drops are judged against.
    fn threshold(&self) -> f64 {
        self.scale * self.sla
    }

    /// Should a request of end-to-end age `age` be dropped when a batch
    /// forms at `stage`?
    pub fn should_drop(&self, stage: usize, age: f64) -> bool {
        self.enabled
            && ((stage > 0 && age > self.threshold()) || age > 2.0 * self.threshold())
    }

    /// Partition a formed batch into (admitted, dropped) by age at
    /// `now`, preserving order.
    pub fn split(&self, stage: usize, now: f64, batch: Vec<Request>) -> (Vec<Request>, Vec<Request>) {
        let mut admitted = Vec::with_capacity(batch.len());
        let mut dropped = Vec::new();
        for req in batch {
            if self.should_drop(stage, now - req.arrival) {
                dropped.push(req);
            } else {
                admitted.push(req);
            }
        }
        (admitted, dropped)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::quickcheck::{check, prop_assert};

    fn req(id: u64, arrival: f64) -> Request {
        Request { id, arrival, stage_arrival: arrival }
    }

    #[test]
    fn entry_stage_tolerates_up_to_2x_sla() {
        let p = DropPolicy::new(1.0, true);
        assert!(!p.should_drop(0, 1.5));
        assert!(p.should_drop(0, 2.5));
        assert!(p.should_drop(1, 1.5));
        assert!(!p.should_drop(1, 0.9));
    }

    #[test]
    fn disabled_never_drops() {
        let p = DropPolicy::new(1.0, false);
        assert!(!p.should_drop(1, 100.0));
        let (kept, dropped) = p.split(1, 100.0, vec![req(0, 0.0), req(1, 0.0)]);
        assert_eq!(kept.len(), 2);
        assert!(dropped.is_empty());
    }

    #[test]
    fn split_partitions_in_order() {
        let p = DropPolicy::new(1.0, true);
        let batch = vec![req(0, 9.5), req(1, 5.0), req(2, 9.8)];
        let (kept, dropped) = p.split(1, 10.0, batch);
        assert_eq!(kept.iter().map(|r| r.id).collect::<Vec<_>>(), vec![0, 2]);
        assert_eq!(dropped.iter().map(|r| r.id).collect::<Vec<_>>(), vec![1]);
    }

    #[test]
    fn scale_moves_the_drop_threshold_not_the_reported_sla() {
        let p = DropPolicy::new(4.0, true).scaled(2.0);
        assert_eq!(p.sla, 4.0, "the true SLA (what metrics judge against) is untouched");
        assert!(!p.should_drop(1, 7.9), "throughput member tolerates up to 2× the SLA");
        assert!(p.should_drop(1, 8.1));
        assert!(!p.should_drop(0, 15.9), "entry-stage ceiling scales too");
        assert!(p.should_drop(0, 16.1));
    }

    /// Property: the rule is monotone in age — if age `a` is dropped at
    /// a stage, every larger age is dropped there too.
    #[test]
    fn prop_drop_monotone_in_age() {
        check("drop monotone in age", 300, |g| {
            let p = DropPolicy::new(g.f64(0.1, 10.0), true);
            let stage = g.usize(0, 4);
            let a = g.f64(0.0, 30.0);
            let b = a + g.f64(0.0, 30.0);
            prop_assert(
                !p.should_drop(stage, a) || p.should_drop(stage, b),
                "larger age survived where smaller was dropped",
            )
        });
    }

    /// Property: entry stage is never stricter than later stages, and
    /// `split` is an order-preserving partition (nothing lost, nothing
    /// duplicated).
    #[test]
    fn prop_split_is_partition() {
        check("split partitions batch", 300, |g| {
            let p = DropPolicy::new(g.f64(0.1, 5.0), g.bool());
            let now = g.f64(10.0, 20.0);
            let stage = g.usize(0, 3);
            let n = g.usize(1, 20);
            let batch: Vec<Request> =
                (0..n as u64).map(|i| req(i, now - g.f64(0.0, 15.0))).collect();
            let (kept, dropped) = p.split(stage, now, batch);
            prop_assert(kept.len() + dropped.len() == n, "sizes don't sum")?;
            let mut ids: Vec<u64> =
                kept.iter().chain(dropped.iter()).map(|r| r.id).collect();
            ids.sort_unstable();
            ids.dedup();
            prop_assert(ids.len() == n, "ids lost or duplicated")?;
            // order preserved within each side
            prop_assert(
                kept.windows(2).all(|w| w[0].id < w[1].id)
                    && dropped.windows(2).all(|w| w[0].id < w[1].id),
                "order not preserved",
            )?;
            // entry stage never stricter: anything stage 0 drops, stage 1
            // drops as well
            for r in &dropped {
                if stage == 0 {
                    prop_assert(
                        p.should_drop(1, now - r.arrival),
                        "stage 0 stricter than stage 1",
                    )?;
                }
            }
            Ok(())
        });
    }
}
