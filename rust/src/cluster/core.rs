//! The clock-agnostic stage machinery every driver runs on.
//!
//! [`StageCore`] holds one stage's variant/batch/replica state plus its
//! busy slots and batcher; [`ClusterCore`] chains the stages, applies
//! the §4.5 [`DropPolicy`] at batch formation, and books every outcome
//! through [`Accounting`].  Time is a parameter — the discrete-event
//! simulator feeds virtual timestamps, the live engine feeds wall-clock
//! ones — so batching, dropping, reconfiguration and bookkeeping are
//! bit-identical across drivers by construction.
//!
//! Rolling updates: [`ClusterCore::apply_config`] changes the formation
//! parameters for FUTURE batches only.  Batches already formed keep the
//! variant/batch captured in their [`FormedBatch`] (old-profile
//! semantics), and a shrink leaves `busy > replicas` until those
//! batches finish — no new work starts on the vanished slots.

use crate::cluster::accounting::Accounting;
use crate::cluster::dispatch::{batch_timeout, BatchDispatcher};
use crate::cluster::drop_policy::DropPolicy;
use crate::optimizer::ip::{PipelineConfig, StageConfig};
use crate::queueing::Request;

/// One stage's live state: active configuration + busy replica slots.
#[derive(Debug)]
pub struct StageCore {
    pub dispatcher: BatchDispatcher,
    pub variant_idx: usize,
    pub variant_key: String,
    pub batch: usize,
    pub replicas: u32,
    /// Replica slots currently serving a batch.
    pub busy: u32,
}

impl StageCore {
    pub fn new(sc: &StageConfig, timeout: f64) -> Self {
        StageCore {
            dispatcher: BatchDispatcher::new(sc.batch, timeout, sc.replicas as usize),
            variant_idx: sc.variant_idx,
            variant_key: sc.variant_key.clone(),
            batch: sc.batch,
            replicas: sc.replicas,
            busy: 0,
        }
    }

    /// Apply a new stage configuration (queued requests stay; in-flight
    /// batches are untouched — rolling update).
    pub fn apply(&mut self, sc: &StageConfig, timeout: f64) {
        self.variant_idx = sc.variant_idx;
        self.variant_key = sc.variant_key.clone();
        self.batch = sc.batch;
        self.replicas = sc.replicas;
        self.dispatcher.set_batch(sc.batch, timeout);
        self.dispatcher.set_replicas(sc.replicas as usize);
    }

    pub fn has_free_replica(&self) -> bool {
        self.busy < self.replicas
    }
}

/// A batch admitted for service, with the configuration captured at
/// formation time (rolling-update semantics: later reconfigurations do
/// not retouch it).
#[derive(Debug, Clone)]
pub struct FormedBatch {
    /// Admitted requests (drop policy already applied), FIFO order.
    pub requests: Vec<Request>,
    /// Round-robin replica slot *label* for the batch (§3 release
    /// order, for routing/telemetry).  Capacity itself is enforced by
    /// [`StageCore`]'s busy/replicas counters, not by this label —
    /// today's drivers treat replicas as anonymous slots.
    pub replica: usize,
    pub variant_idx: usize,
    pub variant_key: String,
    /// Configured batch size at formation (service latency / padding).
    pub batch: usize,
}

/// Outcome of a formation attempt.
#[derive(Debug)]
pub enum FormOutcome {
    /// All replica slots busy — retry when one frees.
    Busy,
    /// Nothing releasable; if a partial batch is pending, the time its
    /// timeout fires.
    Idle { next_timeout: Option<f64> },
    /// A batch started service.
    Formed(FormedBatch),
}

/// The shared cluster: per-stage cores + drop policy + accounting.
#[derive(Debug)]
pub struct ClusterCore {
    pub stages: Vec<StageCore>,
    pub drop_policy: DropPolicy,
    pub accounting: Accounting,
}

impl ClusterCore {
    /// Build from an initial configuration.  `lambda` shapes the batch
    /// timeouts ([`batch_timeout`]); wall-clock drivers pass
    /// `f64::INFINITY` for the bare 50 ms floor.
    pub fn new(init: &PipelineConfig, lambda: f64, drop: DropPolicy) -> Self {
        Self::new_capped(init, lambda, drop, f64::INFINITY)
    }

    /// [`ClusterCore::new`] with a batch-formation timeout ceiling —
    /// the SLA-class hook: latency-critical members cap how long a
    /// partial batch may wait regardless of what the λ-shaped timeout
    /// would allow.  `f64::INFINITY` = uncapped (the classic behavior).
    pub fn new_capped(
        init: &PipelineConfig,
        lambda: f64,
        drop: DropPolicy,
        timeout_cap: f64,
    ) -> Self {
        ClusterCore {
            stages: init
                .stages
                .iter()
                .map(|sc| StageCore::new(sc, batch_timeout(sc.batch, lambda).min(timeout_cap)))
                .collect(),
            accounting: Accounting::new(drop.sla),
            drop_policy: drop,
        }
    }

    pub fn n_stages(&self) -> usize {
        self.stages.len()
    }

    /// Σ configured replicas across the stages — this core's charge
    /// against a shared fleet pool.
    pub fn configured_replicas(&self) -> u32 {
        self.stages.iter().map(|s| s.replicas).sum()
    }

    /// Σ busy slots across the stages.  During a rolling shrink this
    /// can exceed [`configured_replicas`](Self::configured_replicas)
    /// until the in-flight batches drain.
    pub fn busy_replicas(&self) -> u32 {
        self.stages.iter().map(|s| s.busy).sum()
    }

    /// A new request enters the pipeline at `now`.
    pub fn ingest(&mut self, id: u64, now: f64) {
        self.accounting.record_arrival(id, now);
        self.stages[0]
            .dispatcher
            .push(Request { id, arrival: now, stage_arrival: now });
    }

    /// Forward a request that finished stage `stage - 1` into `stage`'s
    /// queue at `now`.
    pub fn forward(&mut self, stage: usize, mut req: Request, now: f64) {
        req.stage_arrival = now;
        self.stages[stage].dispatcher.push(req);
    }

    /// Try to start service on `stage` at `now`: forms a batch if a
    /// replica slot is free and the batcher releases one, applying the
    /// §4.5 drop policy (fully-dropped batches are consumed and the next
    /// one is tried).  Claims a busy slot on success — the driver must
    /// pair every `Formed` with one [`finish_service`](Self::finish_service).
    pub fn try_form(&mut self, stage: usize, now: f64) -> FormOutcome {
        loop {
            let st = &mut self.stages[stage];
            if !st.has_free_replica() {
                return FormOutcome::Busy;
            }
            let Some((batch, replica)) = st.dispatcher.pop_batch(now) else {
                return FormOutcome::Idle { next_timeout: st.dispatcher.next_timeout_at() };
            };
            let (admitted, dropped) = self.drop_policy.split(stage, now, batch);
            for r in &dropped {
                self.accounting.record_drop(r.id);
            }
            if admitted.is_empty() {
                continue; // batch fully dropped; try to form another
            }
            let st = &mut self.stages[stage];
            st.busy += 1;
            return FormOutcome::Formed(FormedBatch {
                requests: admitted,
                replica,
                variant_idx: st.variant_idx,
                variant_key: st.variant_key.clone(),
                batch: st.batch,
            });
        }
    }

    /// A replica slot of `stage` finished its batch.
    pub fn finish_service(&mut self, stage: usize) {
        let st = &mut self.stages[stage];
        st.busy = st.busy.saturating_sub(1);
    }

    /// Record a request leaving the last stage at `now`.
    pub fn complete(&mut self, id: u64, now: f64) {
        self.accounting.record_completion(id, now);
    }

    /// Activate a staged configuration (see [`crate::cluster::reconfig`]).
    pub fn apply_config(&mut self, cfg: &PipelineConfig, lambda: f64) {
        self.apply_config_capped(cfg, lambda, f64::INFINITY);
    }

    /// [`ClusterCore::apply_config`] with a batch-formation timeout
    /// ceiling (see [`ClusterCore::new_capped`]).
    pub fn apply_config_capped(&mut self, cfg: &PipelineConfig, lambda: f64, timeout_cap: f64) {
        for (st, sc) in self.stages.iter_mut().zip(&cfg.stages) {
            st.apply(sc, batch_timeout(sc.batch, lambda).min(timeout_cap));
        }
    }

    /// Consume the core, yielding its accounting (end of run).
    pub fn into_accounting(self) -> Accounting {
        self.accounting
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::quickcheck::{check, prop_assert};

    fn config(stages: &[(usize, u32)]) -> PipelineConfig {
        PipelineConfig {
            stages: stages
                .iter()
                .enumerate()
                .map(|(i, &(batch, replicas))| StageConfig {
                    variant_idx: 0,
                    variant_key: format!("v{i}"),
                    batch,
                    replicas,
                    cost: 1.0,
                    accuracy: 90.0,
                    latency: 0.1,
                    resources: crate::resources::ResourceVec::cpu(1.0),
                })
                .collect(),
            pas: 90.0,
            cost: 2.0,
            batch_sum: stages.iter().map(|s| s.0).sum(),
            objective: 0.0,
            latency_e2e: 0.2,
            resources: crate::resources::ResourceVec::ZERO,
        }
    }

    #[test]
    fn timeout_cap_clamps_batch_formation_waits() {
        // λ=2, batch 8: uncapped timeout = 1.5 × (8-1)/2 = 5.25 s
        let uncapped = ClusterCore::new(&config(&[(8, 1)]), 2.0, DropPolicy::new(10.0, true));
        assert!((uncapped.stages[0].dispatcher.timeout() - 5.25).abs() < 1e-9);
        let capped =
            ClusterCore::new_capped(&config(&[(8, 1)]), 2.0, DropPolicy::new(10.0, true), 0.8);
        assert!((capped.stages[0].dispatcher.timeout() - 0.8).abs() < 1e-9);
        // the cap survives reconfiguration
        let mut capped = capped;
        capped.apply_config_capped(&config(&[(16, 1)]), 2.0, 0.8);
        assert!((capped.stages[0].dispatcher.timeout() - 0.8).abs() < 1e-9);
        // and INFINITY is the identity
        let mut uncapped = uncapped;
        uncapped.apply_config(&config(&[(8, 1)]), 2.0);
        assert!((uncapped.stages[0].dispatcher.timeout() - 5.25).abs() < 1e-9);
    }

    #[test]
    fn forms_batch_when_full_and_replica_free() {
        let mut core =
            ClusterCore::new(&config(&[(2, 1), (1, 1)]), 10.0, DropPolicy::new(1.0, true));
        core.ingest(0, 0.0);
        assert!(matches!(core.try_form(0, 0.0), FormOutcome::Idle { .. }));
        core.ingest(1, 0.01);
        let FormOutcome::Formed(fb) = core.try_form(0, 0.01) else {
            panic!("expected a batch")
        };
        assert_eq!(fb.requests.len(), 2);
        assert_eq!(fb.variant_key, "v0");
        assert_eq!(fb.batch, 2);
        // single replica now busy
        core.ingest(2, 0.02);
        core.ingest(3, 0.02);
        assert!(matches!(core.try_form(0, 0.02), FormOutcome::Busy));
        core.finish_service(0);
        assert!(matches!(core.try_form(0, 0.02), FormOutcome::Formed(_)));
    }

    #[test]
    fn fully_dropped_batch_is_consumed_and_next_tried() {
        let mut core =
            ClusterCore::new(&config(&[(1, 2), (1, 1)]), 10.0, DropPolicy::new(1.0, true));
        core.ingest(0, 0.0);
        core.ingest(1, 5.0);
        // at t=5 the first request is 5s old (> 2×SLA) -> dropped; the
        // second forms the next batch
        let FormOutcome::Formed(fb) = core.try_form(0, 5.0) else {
            panic!("expected a batch")
        };
        assert_eq!(fb.requests[0].id, 1);
        assert!(core.accounting.is_dropped(0));
        assert_eq!(core.accounting.dropped_count(), 1);
    }

    #[test]
    fn rolling_shrink_keeps_inflight_until_done() {
        let mut core =
            ClusterCore::new(&config(&[(1, 2), (1, 1)]), 10.0, DropPolicy::new(1.0, true));
        core.ingest(0, 0.0);
        core.ingest(1, 0.0);
        assert!(matches!(core.try_form(0, 0.0), FormOutcome::Formed(_)));
        assert!(matches!(core.try_form(0, 0.0), FormOutcome::Formed(_)));
        // shrink to 1 replica while 2 batches are in flight
        core.apply_config(&config(&[(1, 1), (1, 1)]), 10.0);
        core.ingest(2, 0.1);
        assert!(matches!(core.try_form(0, 0.1), FormOutcome::Busy));
        core.finish_service(0);
        // still 1 busy >= 1 replica
        assert!(matches!(core.try_form(0, 0.1), FormOutcome::Busy));
        core.finish_service(0);
        assert!(matches!(core.try_form(0, 0.1), FormOutcome::Formed(_)));
    }

    #[test]
    fn reconfig_changes_future_batches_only() {
        let mut core =
            ClusterCore::new(&config(&[(1, 2), (1, 1)]), 10.0, DropPolicy::new(1.0, true));
        core.ingest(0, 0.0);
        let FormOutcome::Formed(before) = core.try_form(0, 0.0) else {
            panic!()
        };
        let mut next = config(&[(4, 2), (1, 1)]);
        next.stages[0].variant_key = "v0b".into();
        next.stages[0].variant_idx = 1;
        core.apply_config(&next, 10.0);
        for id in 1..5 {
            core.ingest(id, 0.1);
        }
        let FormOutcome::Formed(after) = core.try_form(0, 0.1) else {
            panic!()
        };
        assert_eq!(before.variant_key, "v0");
        assert_eq!(before.batch, 1);
        assert_eq!(after.variant_key, "v0b");
        assert_eq!(after.batch, 4);
        assert_eq!(after.variant_idx, 1);
    }

    /// Property: under random driving, conservation holds — every
    /// arrival is completed, dropped, or still in the system, never
    /// more than one of them; busy slots never go negative and formed
    /// batches respect the configured size.
    #[test]
    fn prop_core_conserves_requests() {
        check("core conservation", 60, |g| {
            let n_stages = g.usize(1, 4);
            let mk = |g: &mut crate::util::quickcheck::Gen| {
                let stages: Vec<(usize, u32)> =
                    (0..n_stages).map(|_| (g.pow2(3), g.u64(1, 4) as u32)).collect();
                config(&stages)
            };
            let cfg0 = mk(g);
            let sla = g.f64(0.5, 3.0);
            let mut core = ClusterCore::new(&cfg0, 5.0, DropPolicy::new(sla, g.bool()));
            let mut now = 0.0;
            let mut next_id = 0u64;
            let mut in_service: Vec<(usize, Vec<Request>)> = Vec::new();
            for _ in 0..g.usize(10, 120) {
                now += g.f64(0.0, 0.4);
                match g.usize(0, 4) {
                    0 => {
                        core.ingest(next_id, now);
                        next_id += 1;
                    }
                    1 => {
                        let stage = g.usize(0, n_stages);
                        if let FormOutcome::Formed(fb) = core.try_form(stage, now) {
                            prop_assert(
                                fb.requests.len() <= fb.batch.max(1),
                                "batch over size",
                            )?;
                            in_service.push((stage, fb.requests));
                        }
                    }
                    2 => {
                        if !in_service.is_empty() {
                            let i = g.usize(0, in_service.len());
                            let (stage, reqs) = in_service.swap_remove(i);
                            core.finish_service(stage);
                            if stage + 1 < n_stages {
                                for r in reqs {
                                    core.forward(stage + 1, r, now);
                                }
                            } else {
                                for r in &reqs {
                                    core.complete(r.id, now);
                                }
                            }
                        }
                    }
                    _ => {
                        let cfg = mk(g);
                        core.apply_config(&cfg, 5.0);
                    }
                }
                for st in &core.stages {
                    prop_assert(st.busy as usize <= 64, "busy sane")?;
                }
            }
            let queued: usize = core.stages.iter().map(|s| s.dispatcher.len()).sum();
            let in_flight: usize = in_service.iter().map(|(_, r)| r.len()).sum();
            let acc = core.into_accounting();
            let terminal = acc.completed_count() + acc.dropped_count();
            prop_assert(
                terminal + queued + in_flight == next_id as usize,
                "requests not conserved",
            )?;
            let m = acc.into_metrics("s".into(), "p".into(), "w".into());
            prop_assert(m.requests.len() == next_id as usize, "record per arrival")
        });
    }
}
