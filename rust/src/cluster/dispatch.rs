//! Batch formation + release: the central per-stage queue (§3) and the
//! round-robin replica dispatcher, fused into [`BatchDispatcher`].
//!
//! §3: each pipeline stage has ONE centralized queue (deterministic
//! queueing behaviour, analytically modelable); the queue forms batches
//! of the configured size and round-robins them across the stage's
//! replicas.  This module absorbed `CentralQueue` from `queueing.rs` —
//! the analytic Eq. 7 delay model stays there; the executable machinery
//! lives here, shared verbatim by the simulator, the live engine and
//! the replay driver.

use crate::queueing::{worst_case_delay, Request};

/// Batch-formation timeout: 1.5× the Eq. 7 worst-case wait, floored to
/// 50 ms — partial batches keep latency bounded under thin load.
/// Wall-clock drivers pass `lambda = f64::INFINITY` to opt into the
/// bare 50 ms floor (their λ lives in compressed wall time).
pub fn batch_timeout(batch: usize, lambda: f64) -> f64 {
    (1.5 * worst_case_delay(batch, lambda)).max(0.05)
}

/// Central FIFO queue + batcher for one stage.
///
/// A batch is released when `batch_size` requests are waiting, or when
/// the oldest waiting request has been queued for `timeout` seconds
/// (prevents starvation under low load; the paper's formulation assumes
/// full batches — the timeout is the engineering escape hatch).
#[derive(Debug)]
pub struct CentralQueue {
    pub batch_size: usize,
    pub timeout: f64,
    waiting: std::collections::VecDeque<Request>,
}

impl CentralQueue {
    pub fn new(batch_size: usize, timeout: f64) -> Self {
        Self { batch_size, timeout, waiting: Default::default() }
    }

    pub fn len(&self) -> usize {
        self.waiting.len()
    }

    pub fn is_empty(&self) -> bool {
        self.waiting.is_empty()
    }

    /// Reconfigure (model switch / batch change) — queued requests stay.
    pub fn set_batch(&mut self, batch_size: usize, timeout: f64) {
        self.batch_size = batch_size.max(1);
        self.timeout = timeout;
    }

    pub fn push(&mut self, req: Request) {
        self.waiting.push_back(req);
    }

    /// True if a full batch is ready.
    pub fn full_batch_ready(&self) -> bool {
        self.waiting.len() >= self.batch_size
    }

    /// True if the timeout has expired for the oldest request at `now`.
    pub fn timed_out(&self, now: f64) -> bool {
        self.waiting
            .front()
            .is_some_and(|r| now - r.stage_arrival >= self.timeout)
    }

    /// Absolute time at which the oldest waiting request times out.
    pub fn next_timeout_at(&self) -> Option<f64> {
        self.waiting.front().map(|r| r.stage_arrival + self.timeout)
    }

    /// Pop a batch if one is ready (full, or timed out at `now`).
    /// Timed-out batches may be partial.
    pub fn pop_batch(&mut self, now: f64) -> Option<Vec<Request>> {
        if self.full_batch_ready() {
            return Some(self.drain(self.batch_size));
        }
        if !self.waiting.is_empty() && self.timed_out(now) {
            let n = self.waiting.len().min(self.batch_size);
            return Some(self.drain(n));
        }
        None
    }

    /// Drain everything (used on reconfiguration drains / shutdown).
    pub fn drain_all(&mut self) -> Vec<Request> {
        self.waiting.drain(..).collect()
    }

    fn drain(&mut self, n: usize) -> Vec<Request> {
        self.waiting.drain(..n).collect()
    }
}

/// Round-robin replica dispatcher (§3: queues distribute batched
/// requests across model replicas round-robin).
#[derive(Debug, Clone)]
pub struct RoundRobin {
    n: usize,
    next: usize,
}

impl RoundRobin {
    pub fn new(n: usize) -> Self {
        Self { n: n.max(1), next: 0 }
    }

    pub fn resize(&mut self, n: usize) {
        self.n = n.max(1);
        self.next %= self.n;
    }

    pub fn len(&self) -> usize {
        self.n
    }

    pub fn is_empty(&self) -> bool {
        false
    }

    pub fn pick(&mut self) -> usize {
        let i = self.next;
        self.next = (self.next + 1) % self.n;
        i
    }
}

/// One stage's batcher: central-queue formation + round-robin release.
///
/// Every driver (discrete-event, wall-clock, replay) forms batches
/// through this type, so release rules live in exactly one place.
#[derive(Debug)]
pub struct BatchDispatcher {
    queue: CentralQueue,
    rr: RoundRobin,
}

impl BatchDispatcher {
    pub fn new(batch_size: usize, timeout: f64, replicas: usize) -> Self {
        BatchDispatcher {
            queue: CentralQueue::new(batch_size, timeout),
            rr: RoundRobin::new(replicas),
        }
    }

    pub fn push(&mut self, req: Request) {
        self.queue.push(req);
    }

    pub fn len(&self) -> usize {
        self.queue.len()
    }

    pub fn is_empty(&self) -> bool {
        self.queue.is_empty()
    }

    pub fn batch_size(&self) -> usize {
        self.queue.batch_size
    }

    /// Active batch-formation timeout, seconds.
    pub fn timeout(&self) -> f64 {
        self.queue.timeout
    }

    /// Reconfigure the formation rule — queued requests stay, FIFO
    /// order preserved.
    pub fn set_batch(&mut self, batch_size: usize, timeout: f64) {
        self.queue.set_batch(batch_size, timeout);
    }

    /// Resize the replica ring for round-robin release.
    pub fn set_replicas(&mut self, replicas: usize) {
        self.rr.resize(replicas);
    }

    /// Absolute time the oldest waiting request times out, if any.
    pub fn next_timeout_at(&self) -> Option<f64> {
        self.queue.next_timeout_at()
    }

    /// Pop a ready batch (full, or timed out at `now`) and assign it a
    /// replica slot round-robin.
    pub fn pop_batch(&mut self, now: f64) -> Option<(Vec<Request>, usize)> {
        let batch = self.queue.pop_batch(now)?;
        let replica = self.rr.pick();
        Some((batch, replica))
    }

    /// Drain everything (shutdown).
    pub fn drain_all(&mut self) -> Vec<Request> {
        self.queue.drain_all()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::quickcheck::{check, prop_assert};

    fn req(id: u64, t: f64) -> Request {
        Request { id, arrival: t, stage_arrival: t }
    }

    #[test]
    fn full_batch_release() {
        let mut q = CentralQueue::new(4, 10.0);
        for i in 0..3 {
            q.push(req(i, 0.0));
            assert!(q.pop_batch(0.0).is_none());
        }
        q.push(req(3, 0.1));
        let b = q.pop_batch(0.1).unwrap();
        assert_eq!(b.len(), 4);
        assert_eq!(b[0].id, 0, "FIFO order");
        assert!(q.is_empty());
    }

    #[test]
    fn timeout_releases_partial_batch() {
        let mut q = CentralQueue::new(8, 0.5);
        q.push(req(0, 1.0));
        q.push(req(1, 1.1));
        assert!(q.pop_batch(1.4).is_none());
        let b = q.pop_batch(1.6).unwrap();
        assert_eq!(b.len(), 2);
    }

    #[test]
    fn next_timeout_at_tracks_oldest() {
        let mut q = CentralQueue::new(8, 0.5);
        assert_eq!(q.next_timeout_at(), None);
        q.push(req(0, 2.0));
        q.push(req(1, 2.3));
        assert_eq!(q.next_timeout_at(), Some(2.5));
    }

    #[test]
    fn reconfigure_keeps_queued() {
        let mut q = CentralQueue::new(8, 1.0);
        q.push(req(0, 0.0));
        q.push(req(1, 0.0));
        q.set_batch(2, 1.0);
        let b = q.pop_batch(0.0).unwrap();
        assert_eq!(b.len(), 2);
    }

    #[test]
    fn excess_stays_queued() {
        let mut q = CentralQueue::new(2, 1.0);
        for i in 0..5 {
            q.push(req(i, 0.0));
        }
        assert_eq!(q.pop_batch(0.0).unwrap().len(), 2);
        assert_eq!(q.len(), 3);
    }

    #[test]
    fn round_robin_cycles() {
        let mut rr = RoundRobin::new(3);
        assert_eq!(
            (0..7).map(|_| rr.pick()).collect::<Vec<_>>(),
            vec![0, 1, 2, 0, 1, 2, 0]
        );
        rr.resize(2);
        let picks: Vec<usize> = (0..4).map(|_| rr.pick()).collect();
        assert!(picks.iter().all(|&p| p < 2));
    }

    #[test]
    fn dispatcher_round_robins_replicas() {
        let mut d = BatchDispatcher::new(1, 1.0, 3);
        let mut replicas = Vec::new();
        for i in 0..6 {
            d.push(req(i, 0.0));
            let (b, r) = d.pop_batch(0.0).unwrap();
            assert_eq!(b.len(), 1);
            replicas.push(r);
        }
        assert_eq!(replicas, vec![0, 1, 2, 0, 1, 2]);
    }

    #[test]
    fn batch_timeout_floor_and_scaling() {
        assert_eq!(batch_timeout(1, 10.0), 0.05);
        // 1.5 * (8-1)/2 = 5.25
        assert!((batch_timeout(8, 2.0) - 5.25).abs() < 1e-12);
        // wall-clock drivers: λ = ∞ → bare floor
        assert_eq!(batch_timeout(64, f64::INFINITY), 0.05);
    }

    /// Property: released batches never exceed the configured size,
    /// even while `set_batch` reconfigures mid-stream.
    #[test]
    fn prop_batches_never_exceed_configured_size() {
        check("batch size bound", 200, |g| {
            let mut d = BatchDispatcher::new(g.pow2(6), 0.5, g.usize(1, 8));
            let mut next_id = 0u64;
            let mut now = 0.0;
            for _ in 0..g.usize(1, 40) {
                match g.usize(0, 3) {
                    0 => {
                        d.push(req(next_id, now));
                        next_id += 1;
                    }
                    1 => {
                        now += g.f64(0.0, 1.0);
                        if let Some((b, _)) = d.pop_batch(now) {
                            prop_assert(!b.is_empty(), "batch non-empty")?;
                            prop_assert(
                                b.len() <= d.batch_size(),
                                "batch exceeds configured size",
                            )?;
                        }
                    }
                    _ => {
                        d.set_batch(g.pow2(6), g.f64(0.01, 1.0));
                        d.set_replicas(g.usize(1, 8));
                    }
                }
            }
            Ok(())
        });
    }

    /// Property: FIFO order is preserved across arbitrary `set_batch`
    /// reconfigurations — ids come out in exactly the order they went
    /// in, with nothing lost or duplicated.
    #[test]
    fn prop_queue_order_preserved_across_reconfig() {
        check("queue order across set_batch", 200, |g| {
            let mut d = BatchDispatcher::new(g.pow2(4), 0.2, 2);
            let mut pushed = 0u64;
            let mut popped: Vec<u64> = Vec::new();
            let mut now = 0.0;
            for _ in 0..g.usize(5, 60) {
                match g.usize(0, 4) {
                    0 | 1 => {
                        d.push(req(pushed, now));
                        pushed += 1;
                    }
                    2 => {
                        now += g.f64(0.0, 0.6);
                        while let Some((b, _)) = d.pop_batch(now) {
                            popped.extend(b.iter().map(|r| r.id));
                        }
                    }
                    _ => d.set_batch(g.pow2(4), g.f64(0.01, 0.5)),
                }
            }
            popped.extend(d.drain_all().iter().map(|r| r.id));
            let expect: Vec<u64> = (0..pushed).collect();
            prop_assert(popped == expect, "ids out of order or lost")
        });
    }
}
