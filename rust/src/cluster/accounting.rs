//! Per-request and per-interval bookkeeping shared by every driver,
//! feeding [`RunMetrics`].
//!
//! Terminal states are first-wins: once a request is completed it can
//! never be dropped and vice versa — the conservation invariant the
//! property tests pin down (each arrival ends completed, dropped, or
//! still in flight; never two of them).

use crate::coordinator::adapter::Decision;
use crate::metrics::{IntervalRecord, RequestRecord, RunMetrics};
use crate::optimizer::ip::PipelineConfig;

#[derive(Debug, Clone, Copy, Default)]
struct Flight {
    arrival: f64,
    completion: Option<f64>,
    dropped: bool,
    /// An arrival was recorded for this id.
    seen: bool,
}

/// Run-scoped accounting: request outcomes + the interval configuration
/// series.
#[derive(Debug)]
pub struct Accounting {
    flights: Vec<Flight>,
    intervals: Vec<IntervalRecord>,
    sla: f64,
    completed: usize,
    dropped: usize,
}

impl Accounting {
    pub fn new(sla: f64) -> Self {
        Accounting {
            flights: Vec::new(),
            intervals: Vec::new(),
            sla,
            completed: 0,
            dropped: 0,
        }
    }

    pub fn sla(&self) -> f64 {
        self.sla
    }

    fn slot(&mut self, id: u64) -> &mut Flight {
        let idx = id as usize;
        if idx >= self.flights.len() {
            self.flights.resize(idx + 1, Flight::default());
        }
        &mut self.flights[idx]
    }

    /// Record request `id` entering the pipeline at `t`.
    pub fn record_arrival(&mut self, id: u64, t: f64) {
        let f = self.slot(id);
        f.arrival = t;
        f.seen = true;
    }

    /// Record a §4.5 drop.  No-op if the request already completed.
    pub fn record_drop(&mut self, id: u64) {
        let f = self.slot(id);
        if !f.dropped && f.completion.is_none() {
            f.dropped = true;
            self.dropped += 1;
        }
    }

    /// Record a completion at `t`.  No-op if the request was dropped.
    pub fn record_completion(&mut self, id: u64, t: f64) {
        let f = self.slot(id);
        if !f.dropped && f.completion.is_none() {
            f.completion = Some(t);
            self.completed += 1;
        }
    }

    pub fn is_dropped(&self, id: u64) -> bool {
        self.flights.get(id as usize).map(|f| f.dropped).unwrap_or(false)
    }

    pub fn completed_count(&self) -> usize {
        self.completed
    }

    pub fn dropped_count(&self) -> usize {
        self.dropped
    }

    /// Requests that reached a terminal state (live drivers drain on
    /// this).
    pub fn done(&self) -> usize {
        self.completed + self.dropped
    }

    /// Snapshot one adaptation interval: the ACTIVE configuration's
    /// PAS/cost (the decision only takes effect after the apply delay)
    /// plus the observed and predicted rates behind the new decision.
    pub fn record_interval(
        &mut self,
        t: f64,
        active: &PipelineConfig,
        lambda_observed: f64,
        decision: &Decision,
    ) {
        self.intervals.push(IntervalRecord {
            t,
            pas: active.pas,
            cost: active.cost,
            resources: active.resources,
            lambda_observed,
            lambda_predicted: decision.lambda_predicted,
            decision_time: decision.decision_time,
            variants: active.stages.iter().map(|s| s.variant_key.clone()).collect(),
        });
    }

    /// Finish the run: anything without a terminal state never completed
    /// (still queued / in flight at the horizon).
    pub fn into_metrics(self, system: String, pipeline: String, workload: String) -> RunMetrics {
        let requests: Vec<RequestRecord> = self
            .flights
            .iter()
            .enumerate()
            .filter(|(_, f)| f.seen)
            .map(|(id, f)| RequestRecord {
                id: id as u64,
                arrival: f.arrival,
                completion: if f.dropped { None } else { f.completion },
            })
            .collect();
        RunMetrics {
            system,
            pipeline,
            workload,
            requests,
            intervals: self.intervals,
            sla: self.sla,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::quickcheck::{check, prop_assert};

    #[test]
    fn lifecycle_counts() {
        let mut a = Accounting::new(1.0);
        a.record_arrival(0, 0.1);
        a.record_arrival(1, 0.2);
        a.record_arrival(2, 0.3);
        a.record_completion(0, 0.9);
        a.record_drop(1);
        assert_eq!(a.completed_count(), 1);
        assert_eq!(a.dropped_count(), 1);
        assert_eq!(a.done(), 2);
        assert!(a.is_dropped(1));
        assert!(!a.is_dropped(0));
        let m = a.into_metrics("s".into(), "p".into(), "w".into());
        assert_eq!(m.requests.len(), 3);
        assert_eq!(m.latencies().len(), 1);
        assert!((m.latencies()[0] - 0.8).abs() < 1e-12);
        // id 2 never finished -> counts as dropped in the metrics
        assert_eq!(m.requests.iter().filter(|r| r.dropped()).count(), 2);
    }

    #[test]
    fn terminal_states_are_first_wins() {
        let mut a = Accounting::new(1.0);
        a.record_arrival(0, 0.0);
        a.record_completion(0, 1.0);
        a.record_drop(0); // ignored
        assert_eq!(a.completed_count(), 1);
        assert_eq!(a.dropped_count(), 0);

        a.record_arrival(1, 0.0);
        a.record_drop(1);
        a.record_completion(1, 2.0); // ignored
        assert_eq!(a.completed_count(), 1);
        assert_eq!(a.dropped_count(), 1);
    }

    /// Property: under any interleaving of drops/completions, no request
    /// is ever both dropped and completed, and the terminal counts
    /// partition the terminal set.
    #[test]
    fn prop_no_request_both_dropped_and_completed() {
        check("drop xor complete", 200, |g| {
            let n = g.usize(1, 30) as u64;
            let mut a = Accounting::new(1.0);
            for id in 0..n {
                a.record_arrival(id, id as f64 * 0.01);
            }
            for _ in 0..g.usize(0, 80) {
                let id = g.u64(0, n);
                if g.bool() {
                    a.record_drop(id);
                } else {
                    a.record_completion(id, g.f64(0.0, 10.0));
                }
            }
            let (completed, dropped) = (a.completed_count(), a.dropped_count());
            prop_assert(completed + dropped <= n as usize, "terminal > arrivals")?;
            let m = a.into_metrics("s".into(), "p".into(), "w".into());
            prop_assert(m.requests.len() == n as usize, "one record per arrival")?;
            prop_assert(m.latencies().len() == completed, "completed count")?;
            // everything not completed reads as dropped-or-in-flight:
            // exactly n - completed records have no completion time
            let no_completion = m.requests.iter().filter(|r| r.dropped()).count();
            prop_assert(no_completion == n as usize - completed, "partition")?;
            Ok(())
        });
    }
}
