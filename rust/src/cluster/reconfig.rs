//! Rolling reconfiguration: apply-delay staging of adapter decisions
//! (§5.3's ~8 s adaptation process), shared by every driver.
//!
//! A decision made at `t` becomes active at `t + apply_delay`; until
//! then the old configuration keeps serving.  Batches in flight when
//! the switch lands finish under the profile they started with (the
//! drivers schedule/execute service with the parameters captured at
//! batch formation) — the rolling-update semantics the paper's
//! Kubernetes deployment exhibits.

use std::collections::VecDeque;

use crate::coordinator::adapter::Decision;

/// One staged decision and its activation time.
#[derive(Debug, Clone)]
pub struct Staged {
    pub decision: Decision,
    pub at: f64,
}

/// FIFO stager for decided-but-not-yet-active configurations.
#[derive(Debug)]
pub struct Reconfig {
    pub apply_delay: f64,
    pending: VecDeque<Staged>,
}

impl Reconfig {
    pub fn new(apply_delay: f64) -> Self {
        Reconfig { apply_delay: apply_delay.max(0.0), pending: VecDeque::new() }
    }

    /// Stage `decision` at time `now`; returns its activation time.
    pub fn stage(&mut self, now: f64, decision: Decision) -> f64 {
        let at = now + self.apply_delay;
        self.pending.push_back(Staged { decision, at });
        at
    }

    /// Pop the oldest staged decision whose activation time has come.
    pub fn pop_due(&mut self, now: f64) -> Option<Staged> {
        if self.pending.front().is_some_and(|s| s.at <= now + 1e-9) {
            self.pending.pop_front()
        } else {
            None
        }
    }

    /// Activation time of the next pending decision, if any.
    pub fn next_due(&self) -> Option<f64> {
        self.pending.front().map(|s| s.at)
    }

    pub fn pending_len(&self) -> usize {
        self.pending.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optimizer::ip::PipelineConfig;

    fn decision(pas: f64) -> Decision {
        Decision {
            config: PipelineConfig {
                stages: Vec::new(),
                pas,
                cost: 1.0,
                batch_sum: 0,
                objective: 0.0,
                latency_e2e: 0.0,
                resources: crate::resources::ResourceVec::ZERO,
            },
            lambda_predicted: 10.0,
            decision_time: 0.0,
            fallback: false,
        }
    }

    #[test]
    fn applies_after_delay_in_fifo_order() {
        let mut r = Reconfig::new(8.0);
        assert_eq!(r.stage(10.0, decision(1.0)), 18.0);
        assert_eq!(r.stage(20.0, decision(2.0)), 28.0);
        assert_eq!(r.pending_len(), 2);
        assert!(r.pop_due(17.9).is_none());
        let first = r.pop_due(18.0).unwrap();
        assert_eq!(first.decision.config.pas, 1.0);
        assert!(r.pop_due(18.0).is_none(), "second not due yet");
        assert_eq!(r.next_due(), Some(28.0));
        let second = r.pop_due(30.0).unwrap();
        assert_eq!(second.decision.config.pas, 2.0);
        assert_eq!(r.pending_len(), 0);
    }

    #[test]
    fn zero_delay_is_immediate() {
        let mut r = Reconfig::new(0.0);
        let at = r.stage(5.0, decision(1.0));
        assert_eq!(at, 5.0);
        assert!(r.pop_due(5.0).is_some());
    }
}
