//! Multi-resource allocation vectors.
//!
//! IPA prices a configuration as a scalar `n·R` in CPU cores (Eq. 1
//! base allocations), but real clusters allocate along several axes at
//! once.  [`ResourceVec`] is the demand/capacity vector used end-to-end:
//! every model variant demands one per replica
//! ([`crate::models::registry::Variant::resources`]), every
//! [`crate::fleet::nodes::NodeShape`] offers one per node, and
//! feasibility becomes component-wise dominance ([`ResourceVec::fits`])
//! plus a bin-packing check instead of a scalar budget comparison.
//!
//! The scalar `cost()` every report and objective term uses is a
//! *derived weighted norm* ([`ResourceVec::weighted`]): under the
//! default [`CostWeights`] it weighs CPU cores only, so it equals the
//! paper's `n·R` exactly and every pre-refactor report keeps its
//! numbers.  Memory and accelerator slots still bind — through packing
//! feasibility, not through the default price.

use std::fmt;

/// A point in (CPU cores, memory GB, accelerator slots) space — a
/// replica's demand or a node's capacity.
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct ResourceVec {
    pub cpu_cores: f64,
    pub memory_gb: f64,
    pub accel_slots: f64,
}

/// Comparison slack for the `fits`/`dominates` checks (accumulated
/// float error from repeated `add` must not flip a feasibility verdict).
const EPS: f64 = 1e-9;

impl ResourceVec {
    pub const ZERO: ResourceVec =
        ResourceVec { cpu_cores: 0.0, memory_gb: 0.0, accel_slots: 0.0 };

    pub fn new(cpu_cores: f64, memory_gb: f64, accel_slots: f64) -> ResourceVec {
        ResourceVec { cpu_cores, memory_gb, accel_slots }
    }

    /// A pure-CPU vector (the scalar world embedded in the vector one).
    pub fn cpu(cores: f64) -> ResourceVec {
        ResourceVec { cpu_cores: cores, memory_gb: 0.0, accel_slots: 0.0 }
    }

    pub fn add(self, o: ResourceVec) -> ResourceVec {
        ResourceVec {
            cpu_cores: self.cpu_cores + o.cpu_cores,
            memory_gb: self.memory_gb + o.memory_gb,
            accel_slots: self.accel_slots + o.accel_slots,
        }
    }

    pub fn scale(self, k: f64) -> ResourceVec {
        ResourceVec {
            cpu_cores: self.cpu_cores * k,
            memory_gb: self.memory_gb * k,
            accel_slots: self.accel_slots * k,
        }
    }

    /// Component-wise `self ≤ capacity` (with float slack) — the vector
    /// generalization of the scalar budget check.
    pub fn fits(self, capacity: ResourceVec) -> bool {
        self.cpu_cores <= capacity.cpu_cores + EPS
            && self.memory_gb <= capacity.memory_gb + EPS
            && self.accel_slots <= capacity.accel_slots + EPS
    }

    /// Component-wise `self ≥ other` (with float slack).
    pub fn dominates(self, other: ResourceVec) -> bool {
        other.fits(self)
    }

    /// The derived scalar cost: `w · r`.  Under the default weights this
    /// is exactly the paper's CPU-core price.
    pub fn weighted(self, w: CostWeights) -> f64 {
        w.cpu * self.cpu_cores + w.mem * self.memory_gb + w.accel * self.accel_slots
    }

    pub fn is_finite(self) -> bool {
        self.cpu_cores.is_finite() && self.memory_gb.is_finite() && self.accel_slots.is_finite()
    }

    pub fn non_negative(self) -> bool {
        self.cpu_cores >= 0.0 && self.memory_gb >= 0.0 && self.accel_slots >= 0.0
    }
}

impl fmt::Display for ResourceVec {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}c/{}g/{}a", self.cpu_cores, self.memory_gb, self.accel_slots)
    }
}

/// Weights of the derived scalar cost norm.  The default prices CPU
/// cores only — the unit every Eq. 1/Eq. 9 number in the paper (and
/// every pre-refactor report) is expressed in.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CostWeights {
    pub cpu: f64,
    pub mem: f64,
    pub accel: f64,
}

impl Default for CostWeights {
    fn default() -> Self {
        CostWeights { cpu: 1.0, mem: 0.0, accel: 0.0 }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn arithmetic_and_norm() {
        let a = ResourceVec::new(2.0, 8.0, 1.0);
        let b = ResourceVec::new(1.0, 4.0, 0.0);
        let s = a.add(b);
        assert_eq!(s, ResourceVec::new(3.0, 12.0, 1.0));
        assert_eq!(s.scale(2.0), ResourceVec::new(6.0, 24.0, 2.0));
        // default norm = cpu cores only (the paper's price)
        assert_eq!(s.weighted(CostWeights::default()), 3.0);
        let w = CostWeights { cpu: 1.0, mem: 0.25, accel: 10.0 };
        assert!((s.weighted(w) - (3.0 + 3.0 + 10.0)).abs() < 1e-12);
    }

    #[test]
    fn fits_is_componentwise() {
        let cap = ResourceVec::new(8.0, 32.0, 1.0);
        assert!(ResourceVec::new(8.0, 32.0, 1.0).fits(cap));
        assert!(ResourceVec::new(1.0, 1.0, 0.0).fits(cap));
        assert!(!ResourceVec::new(9.0, 1.0, 0.0).fits(cap), "cpu axis binds");
        assert!(!ResourceVec::new(1.0, 33.0, 0.0).fits(cap), "memory axis binds");
        assert!(!ResourceVec::new(1.0, 1.0, 2.0).fits(cap), "accel axis binds");
        assert!(cap.dominates(ResourceVec::ZERO));
        // float slack: a sum that is equal up to rounding still fits
        let third = ResourceVec::new(8.0 / 3.0, 0.0, 0.0);
        assert!(third.add(third).add(third).fits(ResourceVec::cpu(8.0)));
    }

    #[test]
    fn cpu_embedding_matches_scalar_world() {
        let r = ResourceVec::cpu(4.0);
        assert_eq!(r.memory_gb, 0.0);
        assert_eq!(r.accel_slots, 0.0);
        assert_eq!(r.weighted(CostWeights::default()), 4.0);
        assert_eq!(format!("{r}"), "4c/0g/0a");
    }

    #[test]
    fn finiteness_and_sign_checks() {
        assert!(ResourceVec::new(1.0, 2.0, 0.0).is_finite());
        assert!(!ResourceVec::new(f64::NAN, 0.0, 0.0).is_finite());
        assert!(!ResourceVec::new(0.0, f64::INFINITY, 0.0).is_finite());
        assert!(ResourceVec::ZERO.non_negative());
        assert!(!ResourceVec::new(-1.0, 0.0, 0.0).non_negative());
    }
}
