//! Model-variant registry — the paper's Tables 7–14, verbatim, plus the
//! synthetic-tower geometry shared with `python/compile/registry.py`.
//!
//! Accuracy is *static per-model metadata* in IPA (§4.1: per-stage
//! accuracies are computed offline and are a property of the model), so
//! carrying the published numbers is faithful; the latency/throughput
//! side comes from profiling our real artifacts (or the paper-calibrated
//! analytic profiles — see `profiler::analytic`).

use crate::resources::ResourceVec;

/// Inference task types (one per paper appendix table).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum StageType {
    Detect,
    Classify,
    Audio,
    Qa,
    Summarize,
    Sentiment,
    LangId,
    Nmt,
}

impl StageType {
    pub const ALL: [StageType; 8] = [
        StageType::Detect,
        StageType::Classify,
        StageType::Audio,
        StageType::Qa,
        StageType::Summarize,
        StageType::Sentiment,
        StageType::LangId,
        StageType::Nmt,
    ];

    pub fn name(self) -> &'static str {
        match self {
            StageType::Detect => "detect",
            StageType::Classify => "classify",
            StageType::Audio => "audio",
            StageType::Qa => "qa",
            StageType::Summarize => "summarize",
            StageType::Sentiment => "sentiment",
            StageType::LangId => "langid",
            StageType::Nmt => "nmt",
        }
    }

    pub fn from_name(s: &str) -> Option<StageType> {
        StageType::ALL.iter().copied().find(|t| t.name() == s)
    }

    /// RPS threshold `th` for the Eq. 1 base-allocation solver
    /// (paper Appendix A).
    pub fn threshold_rps(self) -> f64 {
        match self {
            StageType::Detect => 4.0,
            StageType::Classify => 4.0,
            StageType::Audio => 1.0,
            StageType::Qa => 1.0,
            StageType::Summarize => 5.0,
            StageType::Sentiment => 1.0,
            StageType::LangId => 4.0,
            StageType::Nmt => 4.0,
        }
    }

    /// Accuracy-metric name for reports (mAP, Accuracy, 1-WER, ...).
    pub fn metric(self) -> &'static str {
        match self {
            StageType::Detect => "mAP",
            StageType::Classify => "Accuracy",
            StageType::Audio => "1-WER",
            StageType::Qa => "F1",
            StageType::Summarize => "ROUGE-L",
            StageType::Sentiment => "Accuracy",
            StageType::LangId => "Accuracy",
            StageType::Nmt => "BLEU",
        }
    }
}

/// One model variant (a row of Tables 7–14).
#[derive(Debug, Clone, PartialEq)]
pub struct Variant {
    pub stage_type: StageType,
    pub name: &'static str,
    /// Paper parameter count, millions.
    pub params_m: f64,
    /// Paper base allocation, CPU cores (cost per replica).
    pub base_alloc: u32,
    /// Paper accuracy metric value, percent-scale.
    pub accuracy: f64,
}

impl Variant {
    /// `stage.name` key — matches artifact file names and the manifest.
    pub fn key(&self) -> String {
        format!("{}.{}", self.stage_type.name(), self.name)
    }

    /// Synthetic tower width — MUST mirror
    /// `python/compile/registry._hidden_for_params`.
    pub fn hidden(&self) -> usize {
        let h = ((self.params_m.sqrt() * 20.0 / 16.0).round() as i64) * 16;
        h.clamp(32, 512) as usize
    }

    /// Tower depth (python `_LAYERS`).
    pub fn layers(&self) -> usize {
        3
    }

    /// Forward-pass FLOPs at `batch` (2·MACs), mirrors python `flops`.
    pub fn flops(&self, batch: usize) -> u64 {
        let h = self.hidden() as u64;
        2 * batch as u64 * self.layers() as u64 * h * h
    }

    /// Per-replica resource demand vector:
    ///
    /// * `cpu_cores` — the paper's Eq. 1 base allocation, verbatim (so
    ///   the default-weighted norm reproduces the scalar `R_m` price);
    /// * `memory_gb` — fp32 weight footprint (4 B/param) plus a flat
    ///   250 MB runtime overhead, derived from the published parameter
    ///   count;
    /// * `accel_slots` — heavy variants (base allocation ≥ 8 cores)
    ///   are assumed to occupy one accelerator card when the cluster
    ///   offers them; light variants stay CPU-only.
    pub fn resources(&self) -> ResourceVec {
        ResourceVec {
            cpu_cores: self.base_alloc as f64,
            memory_gb: 0.25 + self.params_m * 4.0 / 1000.0,
            accel_slots: if self.base_alloc >= 8 { 1.0 } else { 0.0 },
        }
    }
}

/// Batch sizes profiled/served: powers of two 1..64 (paper §4.2).
pub const BATCH_SIZES: [usize; 7] = [1, 2, 4, 8, 16, 32, 64];

/// The full registry (paper Tables 7–14).
pub const VARIANTS: [Variant; 29] = [
    // Table 7: object detection (YOLOv5, mAP)
    Variant { stage_type: StageType::Detect, name: "yolov5n", params_m: 1.9, base_alloc: 1, accuracy: 45.7 },
    Variant { stage_type: StageType::Detect, name: "yolov5s", params_m: 7.2, base_alloc: 1, accuracy: 56.8 },
    Variant { stage_type: StageType::Detect, name: "yolov5m", params_m: 21.2, base_alloc: 2, accuracy: 64.1 },
    Variant { stage_type: StageType::Detect, name: "yolov5l", params_m: 46.5, base_alloc: 4, accuracy: 67.3 },
    Variant { stage_type: StageType::Detect, name: "yolov5x", params_m: 86.7, base_alloc: 8, accuracy: 68.9 },
    // Table 8: object classification (ResNet, top-1)
    Variant { stage_type: StageType::Classify, name: "resnet18", params_m: 11.7, base_alloc: 1, accuracy: 69.75 },
    Variant { stage_type: StageType::Classify, name: "resnet34", params_m: 21.8, base_alloc: 1, accuracy: 73.31 },
    Variant { stage_type: StageType::Classify, name: "resnet50", params_m: 25.5, base_alloc: 1, accuracy: 76.13 },
    Variant { stage_type: StageType::Classify, name: "resnet101", params_m: 44.54, base_alloc: 1, accuracy: 77.37 },
    Variant { stage_type: StageType::Classify, name: "resnet152", params_m: 60.2, base_alloc: 2, accuracy: 78.31 },
    // Table 9: audio-to-text (1 - WER)
    Variant { stage_type: StageType::Audio, name: "s2t-small", params_m: 29.5, base_alloc: 1, accuracy: 58.72 },
    Variant { stage_type: StageType::Audio, name: "s2t-medium", params_m: 71.2, base_alloc: 2, accuracy: 64.88 },
    Variant { stage_type: StageType::Audio, name: "wav2vec2-base", params_m: 94.4, base_alloc: 2, accuracy: 66.15 },
    Variant { stage_type: StageType::Audio, name: "s2t-large", params_m: 267.8, base_alloc: 4, accuracy: 66.74 },
    Variant { stage_type: StageType::Audio, name: "wav2vec2-large", params_m: 315.5, base_alloc: 8, accuracy: 72.35 },
    // Table 10: question answering (F1)
    Variant { stage_type: StageType::Qa, name: "roberta-base", params_m: 277.45, base_alloc: 1, accuracy: 77.14 },
    Variant { stage_type: StageType::Qa, name: "roberta-large", params_m: 558.8, base_alloc: 1, accuracy: 83.79 },
    // Table 11: summarization (ROUGE-L)
    Variant { stage_type: StageType::Summarize, name: "distilbart-1-1", params_m: 82.9, base_alloc: 1, accuracy: 32.26 },
    Variant { stage_type: StageType::Summarize, name: "distilbart-12-1", params_m: 221.5, base_alloc: 2, accuracy: 33.37 },
    Variant { stage_type: StageType::Summarize, name: "distilbart-6-6", params_m: 229.9, base_alloc: 4, accuracy: 35.73 },
    Variant { stage_type: StageType::Summarize, name: "distilbart-12-3", params_m: 255.1, base_alloc: 8, accuracy: 36.39 },
    Variant { stage_type: StageType::Summarize, name: "distilbart-9-6", params_m: 267.7, base_alloc: 8, accuracy: 36.61 },
    Variant { stage_type: StageType::Summarize, name: "distilbart-12-6", params_m: 305.5, base_alloc: 16, accuracy: 36.99 },
    // Table 12: sentiment analysis (accuracy)
    Variant { stage_type: StageType::Sentiment, name: "distilbert", params_m: 66.9, base_alloc: 1, accuracy: 79.6 },
    Variant { stage_type: StageType::Sentiment, name: "bert", params_m: 109.4, base_alloc: 1, accuracy: 79.9 },
    Variant { stage_type: StageType::Sentiment, name: "roberta", params_m: 355.3, base_alloc: 1, accuracy: 83.0 },
    // Table 13: language identification (accuracy)
    Variant { stage_type: StageType::LangId, name: "roberta-lid", params_m: 278.0, base_alloc: 1, accuracy: 79.62 },
    // Table 14: neural machine translation (BLEU)
    Variant { stage_type: StageType::Nmt, name: "opus-mt-fr-en", params_m: 74.6, base_alloc: 4, accuracy: 33.1 },
    Variant { stage_type: StageType::Nmt, name: "opus-mt-big-fr-en", params_m: 230.6, base_alloc: 8, accuracy: 34.4 },
];

/// All variants of a stage type, in registry (ascending-size) order.
pub fn variants_of(t: StageType) -> Vec<&'static Variant> {
    VARIANTS.iter().filter(|v| v.stage_type == t).collect()
}

/// Look up a variant by `stage.name` key.
pub fn by_key(key: &str) -> Option<&'static Variant> {
    VARIANTS.iter().find(|v| v.key() == key)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_counts_match_paper() {
        assert_eq!(variants_of(StageType::Detect).len(), 5);
        assert_eq!(variants_of(StageType::Classify).len(), 5);
        assert_eq!(variants_of(StageType::Audio).len(), 5);
        assert_eq!(variants_of(StageType::Qa).len(), 2);
        assert_eq!(variants_of(StageType::Summarize).len(), 6);
        assert_eq!(variants_of(StageType::Sentiment).len(), 3);
        assert_eq!(variants_of(StageType::LangId).len(), 1);
        assert_eq!(variants_of(StageType::Nmt).len(), 2);
        assert_eq!(VARIANTS.len(), 29);
    }

    #[test]
    fn accuracy_monotone_in_params_within_stage() {
        // The paper's premise: bigger variants of a task are more accurate.
        for t in StageType::ALL {
            let vs = variants_of(t);
            for w in vs.windows(2) {
                assert!(w[0].params_m < w[1].params_m, "{t:?} ordering");
                assert!(w[0].accuracy <= w[1].accuracy, "{t:?} accuracy monotone");
            }
        }
    }

    #[test]
    fn hidden_dims_tile_friendly_and_bounded() {
        for v in &VARIANTS {
            let h = v.hidden();
            assert_eq!(h % 16, 0, "{}", v.key());
            assert!((32..=512).contains(&h), "{}", v.key());
        }
    }

    #[test]
    fn known_hidden_values_match_python_registry() {
        // Spot values pinned against python/compile/registry.py.
        assert_eq!(by_key("detect.yolov5n").unwrap().hidden(), 32);
        assert_eq!(by_key("qa.roberta-large").unwrap().hidden(), 480);
        assert_eq!(by_key("classify.resnet18").unwrap().hidden(), 64);
    }

    #[test]
    fn key_lookup() {
        assert!(by_key("detect.yolov5x").is_some());
        assert!(by_key("detect.nonexistent").is_none());
        assert_eq!(by_key("audio.s2t-large").unwrap().base_alloc, 4);
    }

    #[test]
    fn resource_vectors_derive_from_the_tables() {
        use crate::resources::CostWeights;
        for v in &VARIANTS {
            let r = v.resources();
            assert!(r.is_finite() && r.non_negative(), "{}", v.key());
            // default-weighted norm == the paper's scalar base allocation
            assert_eq!(r.weighted(CostWeights::default()), v.base_alloc as f64, "{}", v.key());
            // memory grows with parameter count, never below the overhead
            assert!(r.memory_gb > 0.25, "{}", v.key());
            // only heavy variants demand an accelerator slot
            assert_eq!(r.accel_slots > 0.0, v.base_alloc >= 8, "{}", v.key());
        }
        // spot values: yolov5x is the canonical accel-demanding variant
        let x = by_key("detect.yolov5x").unwrap().resources();
        assert_eq!(x.cpu_cores, 8.0);
        assert_eq!(x.accel_slots, 1.0);
        let n = by_key("detect.yolov5n").unwrap().resources();
        assert_eq!(n.accel_slots, 0.0);
        assert!(n.memory_gb < x.memory_gb, "memory tracks parameter count");
    }

    #[test]
    fn flops_scale_linearly_with_batch() {
        let v = by_key("classify.resnet50").unwrap();
        assert_eq!(v.flops(64), 64 * v.flops(1));
    }

    #[test]
    fn table5_paper_base_allocs() {
        // Table 7 BA column (used as cost weights in the e2e experiments).
        let d: Vec<u32> = variants_of(StageType::Detect).iter().map(|v| v.base_alloc).collect();
        assert_eq!(d, vec![1, 1, 2, 4, 8]);
        let s: Vec<u32> = variants_of(StageType::Summarize).iter().map(|v| v.base_alloc).collect();
        assert_eq!(s, vec![1, 2, 4, 8, 8, 16]);
    }
}
