//! Pipeline accuracy metrics.
//!
//! §4.1: with independent per-stage errors, IPA scores a pipeline
//! configuration by the *product* of the active variants' accuracies —
//! the Pipeline Accuracy Score (PAS).  Appendix C defines an alternative
//! PAS′ that sums rank-normalized per-stage accuracies; Figs. 17/18 show
//! both metrics produce the same system ordering.

use super::registry::{variants_of, StageType};

/// PAS (Eq. 8): percent-scale product, `100 · Π (aₛ/100)`.
///
/// For a two-stage pipeline with accuracies 72.35 and 83.0 this yields
/// ~60.1 — matching the "average PAS of 59" scale in §5.4.
pub fn pas(stage_accuracies: &[f64]) -> f64 {
    100.0 * stage_accuracies.iter().map(|a| a / 100.0).product::<f64>()
}

/// Rank-normalized accuracy of one variant within its stage's option set
/// (Appendix C): least-accurate → 0, most-accurate → 1, linear in rank.
pub fn normalized_rank(stage: StageType, accuracy: f64) -> f64 {
    let vs = variants_of(stage);
    if vs.len() <= 1 {
        return 1.0;
    }
    // Registry order is ascending accuracy (tested in registry.rs).
    let mut rank = 0usize;
    for (i, v) in vs.iter().enumerate() {
        if (v.accuracy - accuracy).abs() < 1e-9 {
            rank = i;
            break;
        }
    }
    rank as f64 / (vs.len() - 1) as f64
}

/// PAS′ (Eq. 11): sum of rank-normalized per-stage accuracies.
pub fn pas_prime(stages: &[StageType], stage_accuracies: &[f64]) -> f64 {
    assert_eq!(stages.len(), stage_accuracies.len());
    stages
        .iter()
        .zip(stage_accuracies)
        .map(|(s, a)| normalized_rank(*s, *a))
        .sum()
}

/// Which accuracy metric the optimizer maximizes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AccuracyMetric {
    /// Eq. 8 product (the paper's primary metric).
    Pas,
    /// Eq. 11 normalized sum (Appendix C ablation).
    PasPrime,
}

impl AccuracyMetric {
    /// Evaluate the metric for a configuration's per-stage accuracies.
    pub fn eval(self, stages: &[StageType], accs: &[f64]) -> f64 {
        match self {
            AccuracyMetric::Pas => pas(accs),
            AccuracyMetric::PasPrime => pas_prime(stages, accs),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pas_product_scale() {
        // audio-sent best config: wav2vec2-large (72.35) x roberta (83.0)
        let v = pas(&[72.35, 83.0]);
        assert!((v - 60.05).abs() < 0.05, "{v}");
        // single stage degenerates to the stage accuracy
        assert!((pas(&[70.0]) - 70.0).abs() < 1e-9);
    }

    #[test]
    fn pas_monotone_in_each_stage() {
        assert!(pas(&[50.0, 80.0]) < pas(&[60.0, 80.0]));
        assert!(pas(&[50.0, 80.0]) < pas(&[50.0, 90.0]));
    }

    #[test]
    fn normalized_rank_endpoints() {
        // detect: yolov5n is least accurate (0), yolov5x most (1).
        assert_eq!(normalized_rank(StageType::Detect, 45.7), 0.0);
        assert_eq!(normalized_rank(StageType::Detect, 68.9), 1.0);
        // middle variant of 5 -> 0.5
        assert!((normalized_rank(StageType::Detect, 64.1) - 0.5).abs() < 1e-9);
        // single-variant stage -> 1.0
        assert_eq!(normalized_rank(StageType::LangId, 79.62), 1.0);
    }

    #[test]
    fn pas_prime_sum() {
        let stages = [StageType::Detect, StageType::Classify];
        // second-most-accurate of 5 in each stage -> 0.75 + 0.75
        let v = pas_prime(&stages, &[67.3, 77.37]);
        assert!((v - 1.5).abs() < 1e-9, "{v}");
    }

    #[test]
    fn both_metrics_agree_on_ordering() {
        // Appendix C claim: PAS and PAS' rank configurations the same way
        // when moving a single stage up the accuracy ladder.
        let stages = [StageType::Detect, StageType::Classify];
        let lo = [45.7, 69.75];
        let hi = [68.9, 78.31];
        assert!(pas(&lo) < pas(&hi));
        assert!(pas_prime(&stages, &lo) < pas_prime(&stages, &hi));
    }
}
