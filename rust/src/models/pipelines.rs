//! The five paper pipelines (Fig. 6) with their objective weights
//! (Table 15) and per-stage SLA targets (Table 6).

use super::registry::StageType;

/// Objective weights of Eq. 9: `α·PAS − β·Σ nR − δ·Σ b` (Table 15).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ObjectiveWeights {
    pub alpha: f64,
    pub beta: f64,
    pub delta: f64,
}

/// One inference pipeline: an ordered chain of stage types.
#[derive(Debug, Clone)]
pub struct PipelineSpec {
    pub name: &'static str,
    pub stages: Vec<StageType>,
    pub weights: ObjectiveWeights,
    /// Paper Table 6 per-stage latency SLAs, seconds.  The analytic
    /// profiles are calibrated so that `SLA_s = 5 × avg(b=1 latency)`
    /// (§4.2 / Swayam rule) reproduces these numbers exactly.
    pub stage_slas: Vec<f64>,
}

impl PipelineSpec {
    /// End-to-end SLA: `SLA_P = Σ SLA_s` (§4.2).
    pub fn sla_e2e(&self) -> f64 {
        self.stage_slas.iter().sum()
    }

    pub fn n_stages(&self) -> usize {
        self.stages.len()
    }

    /// Size of the per-interval configuration space:
    /// Π |M_s| × |batches| × n_max (reported in §5.2 as 5×5=25 for video
    /// in variant terms).
    pub fn variant_space(&self) -> usize {
        self.stages
            .iter()
            .map(|s| super::registry::variants_of(*s).len())
            .product()
    }
}

/// All five paper pipelines.
///
/// Table 6 SLAs (seconds) and Table 15 weights are carried verbatim.
/// NLP stage order follows Fig. 6(e): language-id → summarize → translate
/// (the 12.76 s middle-stage SLA belongs to the summarizer, the heaviest
/// task family).
pub fn all() -> Vec<PipelineSpec> {
    vec![
        PipelineSpec {
            name: "video",
            stages: vec![StageType::Detect, StageType::Classify],
            weights: ObjectiveWeights { alpha: 2.0, beta: 1.0, delta: 1e-6 },
            stage_slas: vec![4.62, 2.27],
        },
        PipelineSpec {
            name: "audio-qa",
            stages: vec![StageType::Audio, StageType::Qa],
            weights: ObjectiveWeights { alpha: 10.0, beta: 0.5, delta: 1e-6 },
            stage_slas: vec![8.34, 0.89],
        },
        PipelineSpec {
            name: "audio-sent",
            stages: vec![StageType::Audio, StageType::Sentiment],
            weights: ObjectiveWeights { alpha: 30.0, beta: 0.5, delta: 1e-6 },
            stage_slas: vec![8.34, 1.08],
        },
        PipelineSpec {
            name: "sum-qa",
            stages: vec![StageType::Summarize, StageType::Qa],
            weights: ObjectiveWeights { alpha: 10.0, beta: 0.5, delta: 1e-6 },
            stage_slas: vec![2.52, 1.32],
        },
        PipelineSpec {
            name: "nlp",
            stages: vec![StageType::LangId, StageType::Summarize, StageType::Nmt],
            weights: ObjectiveWeights { alpha: 40.0, beta: 0.5, delta: 1e-6 },
            stage_slas: vec![0.97, 12.76, 3.87],
        },
    ]
}

/// Look up a pipeline by name.
pub fn by_name(name: &str) -> Option<PipelineSpec> {
    all().into_iter().find(|p| p.name == name)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn five_pipelines() {
        assert_eq!(all().len(), 5);
    }

    #[test]
    fn table6_e2e_slas() {
        // Paper Table 6 E2E column.
        let cases = [
            ("video", 6.89),
            ("audio-qa", 9.23),
            ("audio-sent", 9.42),
            ("sum-qa", 3.84),
            ("nlp", 17.61),
        ];
        for (name, e2e) in cases {
            let p = by_name(name).unwrap();
            // tolerance: the paper's E2E column rounds (0.97+12.76+3.87
            // prints as 17.61 but sums to 17.60)
            assert!((p.sla_e2e() - e2e).abs() < 0.011, "{name}: {}", p.sla_e2e());
        }
    }

    #[test]
    fn table15_weights() {
        assert_eq!(by_name("video").unwrap().weights.alpha, 2.0);
        assert_eq!(by_name("nlp").unwrap().weights.alpha, 40.0);
        assert_eq!(by_name("audio-sent").unwrap().weights.alpha, 30.0);
        for p in all() {
            assert_eq!(p.weights.delta, 1e-6);
        }
    }

    #[test]
    fn variant_space_matches_paper() {
        // §5.2: 5×5=25 for video, 5×2 audio-qa, 5×3 audio-sent.
        assert_eq!(by_name("video").unwrap().variant_space(), 25);
        assert_eq!(by_name("audio-qa").unwrap().variant_space(), 10);
        assert_eq!(by_name("audio-sent").unwrap().variant_space(), 15);
        assert_eq!(by_name("sum-qa").unwrap().variant_space(), 12);
        assert_eq!(by_name("nlp").unwrap().variant_space(), 12);
    }

    #[test]
    fn stage_counts() {
        assert_eq!(by_name("nlp").unwrap().n_stages(), 3);
        assert_eq!(by_name("video").unwrap().n_stages(), 2);
    }
}
