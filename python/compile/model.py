"""L2: JAX compute graphs for the synthetic model variants.

Each of the 29 registry variants (registry.py, mirroring the paper's
Tables 7-14) becomes an MLP tower whose every layer runs the L1 Pallas
matmul kernel.  The towers are what the Rust serving engine executes per
batched request — the stand-in for YOLOv5/ResNet/RoBERTa forward passes
(see DESIGN.md substitution table).

Weights are *runtime inputs*, not baked constants: this keeps the HLO
text small (no dense literals) and lets the Rust runtime keep the weight
literals resident as device buffers across calls.  The Rust side
generates the same seeded weights (util::rng::SplitMix64) so the AOT
check values in the manifest can be verified end-to-end.
"""

from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from .kernels.matmul import linear
from .kernels import ref
from .registry import VariantSpec


def make_forward(spec: VariantSpec, batch: int):
    """Build the jittable forward fn for `spec` at a fixed batch size.

    Signature: fwd(x[batch, hidden], W1, b1, ..., WL, bL) -> ([batch, hidden],)
    (1-tuple because aot.py lowers with return_tuple=True).
    """

    n_layers = spec.layers

    def fwd(x, *params):
        assert len(params) == 2 * n_layers
        for li in range(n_layers):
            w, b = params[2 * li], params[2 * li + 1]
            act = jax.nn.relu if li < n_layers - 1 else None
            x = linear(x, w, b, activation=act)
        return (x,)

    return fwd


def make_ref_forward(spec: VariantSpec):
    """Pure-jnp oracle tower (any batch), for tests and AOT check values."""

    def fwd(x, *params):
        return (ref.ref_tower(x, list(params)),)

    return fwd


def input_spec(spec: VariantSpec, batch: int) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct((batch, spec.hidden), jnp.float32)


def param_specs(spec: VariantSpec) -> List[jax.ShapeDtypeStruct]:
    out = []
    for (w_shape, b_shape) in spec.param_shapes():
        out.append(jax.ShapeDtypeStruct(w_shape, jnp.float32))
        out.append(jax.ShapeDtypeStruct(b_shape, jnp.float32))
    return out


# ---------------------------------------------------------------------------
# Deterministic weight generation shared with the Rust runtime.
#
# SplitMix64 seeded by fnv1a64(variant_key) XOR a per-tensor index; each u64
# is mapped to f32 in [-0.5, 0.5) scaled by 1/sqrt(fan_in).  The Rust side
# (rust/src/runtime/weights.rs) reimplements exactly this.
# ---------------------------------------------------------------------------

_MASK64 = (1 << 64) - 1


def fnv1a64(s: str) -> int:
    h = 0xCBF29CE484222325
    for ch in s.encode():
        h ^= ch
        h = (h * 0x100000001B3) & _MASK64
    return h


def splitmix64_fill(seed: int, n: int) -> np.ndarray:
    """n uniform f32 in [-0.5, 0.5), bit-exact with the Rust generator."""
    out = np.empty(n, dtype=np.float32)
    state = seed & _MASK64
    for idx in range(n):
        state = (state + 0x9E3779B97F4A7C15) & _MASK64
        z = state
        z = ((z ^ (z >> 30)) * 0xBF58476D1CE4E5B9) & _MASK64
        z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & _MASK64
        z = z ^ (z >> 31)
        # top 24 bits -> [0, 1) with exact f32 representation
        out[idx] = np.float32((z >> 40) / float(1 << 24)) - np.float32(0.5)
    return out


def make_params(spec: VariantSpec) -> List[np.ndarray]:
    """Seeded weights for `spec`, scaled by 1/sqrt(fan_in) (keeps
    activations O(1) through the tower so check values are well-behaved)."""
    base = fnv1a64(spec.key)
    params: List[np.ndarray] = []
    for ti, (w_shape, b_shape) in enumerate(spec.param_shapes()):
        fan_in = w_shape[0]
        scale = np.float32(1.0 / np.sqrt(fan_in))
        w = splitmix64_fill(base ^ (2 * ti + 1), w_shape[0] * w_shape[1])
        params.append((w * scale).reshape(w_shape))
        b = splitmix64_fill(base ^ (2 * ti + 2), b_shape[0])
        params.append((b * np.float32(0.1)).reshape(b_shape))
    return params


def check_input(spec: VariantSpec, batch: int) -> np.ndarray:
    """Deterministic check input: ones / sqrt(hidden)."""
    return np.full((batch, spec.hidden),
                   1.0 / np.sqrt(spec.hidden), dtype=np.float32)


def check_value(spec: VariantSpec, batch: int = 1) -> float:
    """Sum of the reference tower output on the check input — stored in the
    manifest and re-verified by the Rust runtime tests."""
    x = jnp.asarray(check_input(spec, batch))
    params = [jnp.asarray(p) for p in make_params(spec)]
    (y,) = make_ref_forward(spec)(x, *params)
    return float(jnp.sum(y))
