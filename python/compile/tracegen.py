"""Synthetic Twitter-shaped workload traces (build-path side).

The paper evaluates on four excerpts of the archiveteam Twitter stream
trace (bursty / fluctuating / steady-low / steady-high) plus a 14-day
training prefix for the LSTM predictor.  The real trace is not available
here (repro gate), so this module generates deterministic synthetic
traces reproducing those archetypes.

DETERMINISM CONTRACT: this file is a line-for-line algorithmic twin of
rust/src/workload/tracegen.rs.  Both use SplitMix64 and only +,-,*,/ on
f64 (no libm transcendentals), so the two implementations produce
bit-identical rate sequences for the same (pattern, seed).  The LSTM is
trained on traces from this generator and serves predictions (in Rust,
via PJRT) on traces from the Rust twin.
"""

from typing import List

_MASK64 = (1 << 64) - 1


class SplitMix64:
    """Tiny deterministic PRNG; twin of rust/src/util/rng.rs."""

    def __init__(self, seed: int):
        self.state = seed & _MASK64

    def next_u64(self) -> int:
        self.state = (self.state + 0x9E3779B97F4A7C15) & _MASK64
        z = self.state
        z = ((z ^ (z >> 30)) * 0xBF58476D1CE4E5B9) & _MASK64
        z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & _MASK64
        return z ^ (z >> 31)

    def next_f64(self) -> float:
        """Uniform in [0, 1), using the top 53 bits (bit-exact across langs)."""
        return (self.next_u64() >> 11) / float(1 << 53)

    def range_f64(self, lo: float, hi: float) -> float:
        return lo + (hi - lo) * self.next_f64()


def bump(phase: float) -> float:
    """Smooth periodic bump in [0,1]: parabola 1-(2p-1)^2 over each period.

    Deterministic substitute for sin() — libm results differ across
    languages, polynomials do not.
    """
    p = phase - int(phase)
    if p < 0.0:
        p += 1.0
    d = 2.0 * p - 1.0
    return 1.0 - d * d


class _Burst:
    __slots__ = ("start", "ramp", "hold", "decay", "amp")

    def __init__(self, start, ramp, hold, decay, amp):
        self.start, self.ramp, self.hold, self.decay, self.amp = \
            start, ramp, hold, decay, amp

    def value(self, t: float) -> float:
        dt = t - self.start
        if dt < 0.0:
            return 0.0
        if dt < self.ramp:
            return self.amp * dt / self.ramp
        dt -= self.ramp
        if dt < self.hold:
            return self.amp
        dt -= self.hold
        if dt < self.decay:
            return self.amp * (1.0 - dt / self.decay)
        return 0.0


def _gen_bursts(rng: SplitMix64, seconds: int, mean_gap: float,
                amp_lo: float, amp_hi: float) -> List[_Burst]:
    bursts = []
    t = rng.range_f64(5.0, mean_gap)
    while t < seconds:
        ramp = rng.range_f64(3.0, 8.0)
        hold = rng.range_f64(10.0, 30.0)
        decay = rng.range_f64(5.0, 15.0)
        amp = rng.range_f64(amp_lo, amp_hi)
        bursts.append(_Burst(t, ramp, hold, decay, amp))
        t += ramp + hold + decay + rng.range_f64(0.5 * mean_gap, 1.5 * mean_gap)
    return bursts


PATTERNS = ("steady_low", "steady_high", "fluctuating", "bursty", "composite")

# Length of one synthetic "day" in the composite (LSTM-training) trace.
DAY_SECONDS = 2400


def generate(pattern: str, seconds: int, seed: int) -> List[float]:
    """Per-second arrival rates (RPS), length `seconds`.

    Archetypes (paper Fig 7): steady_low ~6 RPS, steady_high ~26 RPS,
    fluctuating 6..26 RPS waves, bursty 8 RPS base with 20-35 RPS spikes.
    `composite` is the 21-"day" diurnal+bursts trace used to train and
    evaluate the LSTM predictor (14 days train / 7 days held out).
    """
    rng = SplitMix64(seed)
    rates = [0.0] * seconds

    if pattern == "steady_low":
        for t in range(seconds):
            rates[t] = 6.0 + rng.range_f64(-0.8, 0.8)
    elif pattern == "steady_high":
        for t in range(seconds):
            rates[t] = 26.0 + rng.range_f64(-2.0, 2.0)
    elif pattern == "fluctuating":
        for t in range(seconds):
            wave = 20.0 * bump(t / 300.0)
            rates[t] = 6.0 + wave + rng.range_f64(-1.5, 1.5)
    elif pattern == "bursty":
        bursts = _gen_bursts(rng, seconds, 120.0, 18.0, 30.0)
        for t in range(seconds):
            v = 8.0 + rng.range_f64(-1.0, 1.0)
            for b in bursts:
                v += b.value(float(t))
            rates[t] = v
    elif pattern == "composite":
        # burst distribution matches the bursty eval archetype (amp
        # 18-30) so the LSTM learns to anticipate real burst onsets
        bursts = _gen_bursts(rng, seconds, 150.0, 16.0, 30.0)
        for t in range(seconds):
            day_phase = t / float(DAY_SECONDS)
            diurnal = 16.0 * bump(day_phase)
            # slow multi-day modulation (period ~5.3 days)
            weekly = 4.0 * bump(day_phase / 5.3)
            v = 5.0 + diurnal + weekly + rng.range_f64(-1.2, 1.2)
            for b in bursts:
                v += b.value(float(t))
            rates[t] = v
    else:
        raise ValueError(f"unknown pattern {pattern!r}")

    for t in range(seconds):
        if rates[t] < 0.5:
            rates[t] = 0.5
    return rates
