"""IPA load predictor: LSTM trained in JAX at build time (L2).

Paper §3 "Predictor": an LSTM that, every adaptation interval, takes the
per-second load of the past 2 minutes (HISTORY=120) and predicts the
*maximum* load over the next 20 seconds (HORIZON=20).  The paper trains
on 14 days of the Twitter trace; we train on the first 14 synthetic
"days" of the composite trace (tracegen.py) and hold out the last 7.

The exported artifact (aot.py) is the forward pass ONLY, with trained
weights baked in, built on the L1 fused-LSTM-cell Pallas kernel — so the
predictor runs in Rust via PJRT on the adaptation path with no Python.
"""

from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import tracegen
from .kernels.lstm_cell import lstm_cell
from .kernels.ref import ref_lstm_cell

HISTORY = 120       # seconds of per-second load fed to the LSTM
HORIZON = 20        # predict max load over the next HORIZON seconds
HIDDEN = 32         # LSTM width (paper: 25; rounded up for tile alignment)
SCALE = 50.0        # load normalization divisor (traces peak ~45 RPS)

TRAIN_DAYS = 14
TEST_DAYS = 7
TRACE_SEED = 0x7717_7E2A

# Pinball (quantile) loss target: under-predicting the peak causes SLA
# violations while over-predicting only costs cores, so the predictor
# trains toward the 0.8-quantile of the next-horizon max (measured:
# under-prediction windows drop 34% -> 16% at ~1.1x mean provisioning).
TAU = 0.8


def init_params(seed: int = 3) -> Dict[str, jnp.ndarray]:
    k = jax.random.PRNGKey(seed)
    k1, k2, k3 = jax.random.split(k, 3)
    s_in = 1.0 / np.sqrt(1 + HIDDEN)
    return {
        "wx": jax.random.normal(k1, (1, 4 * HIDDEN)) * s_in,
        "wh": jax.random.normal(k2, (HIDDEN, 4 * HIDDEN)) * s_in,
        "b": jnp.zeros((4 * HIDDEN,)),
        "wd": jax.random.normal(k3, (HIDDEN, 1)) * (1.0 / np.sqrt(HIDDEN)),
        "bd": jnp.zeros((1,)),
    }


def forward_batch(params, x):
    """Training-path forward (pure jnp): x[B, HISTORY] -> [B] prediction."""
    bsz = x.shape[0]
    h = jnp.zeros((bsz, HIDDEN), jnp.float32)
    c = jnp.zeros((bsz, HIDDEN), jnp.float32)

    def step(carry, xt):
        h, c = carry
        h, c = ref_lstm_cell(xt[:, None], h, c,
                             params["wx"], params["wh"], params["b"])
        return (h, c), None

    (h, _), _ = jax.lax.scan(step, (h, c), x.T)
    y = h @ params["wd"] + params["bd"]
    return y[:, 0]


def make_export_forward(params_np: Dict[str, np.ndarray]):
    """Inference-path forward for AOT export: the scan body is the L1
    fused Pallas cell, weights are baked constants (they are tiny), and
    the output is denormalized to RPS.

    Signature: fwd(window[1, HISTORY]) -> ([1] predicted max RPS,)
    """
    consts = {k: jnp.asarray(v, jnp.float32) for k, v in params_np.items()}

    def fwd(window):
        x = window / SCALE
        h = jnp.zeros((1, HIDDEN), jnp.float32)
        c = jnp.zeros((1, HIDDEN), jnp.float32)

        def step(carry, xt):
            h, c = carry
            h, c = lstm_cell(xt[None, None], h, c,
                             consts["wx"], consts["wh"], consts["b"])
            return (h, c), None

        (h, _), _ = jax.lax.scan(step, (h, c), x[0])
        y = h @ consts["wd"] + consts["bd"]
        return (y[0] * SCALE,)

    return fwd


def build_windows(rates: List[float], start: int, end: int,
                  stride: int = 7) -> Tuple[np.ndarray, np.ndarray]:
    """(x[N, HISTORY], y[N]) normalized windows from rates[start:end]."""
    xs, ys = [], []
    r = np.asarray(rates, dtype=np.float32)
    t = max(start, HISTORY)
    while t + HORIZON <= end:
        xs.append(r[t - HISTORY:t])
        ys.append(r[t:t + HORIZON].max())
        t += stride
    x = np.stack(xs) / SCALE
    y = np.asarray(ys, dtype=np.float32) / SCALE
    return x, y


def smape(pred: np.ndarray, true: np.ndarray) -> float:
    """Symmetric mean absolute percentage error (paper reports 6.6%)."""
    denom = (np.abs(pred) + np.abs(true)) / 2.0
    return float(np.mean(np.abs(pred - true) / np.maximum(denom, 1e-6)) * 100)


def train(steps: int = 400, batch: int = 256, lr: float = 8e-3,
          seed: int = 3, log=lambda *_: None):
    """Train the predictor; returns (params_np, metrics)."""
    total = (TRAIN_DAYS + TEST_DAYS) * tracegen.DAY_SECONDS
    rates = tracegen.generate("composite", total, TRACE_SEED)
    split = TRAIN_DAYS * tracegen.DAY_SECONDS
    x_tr, y_tr = build_windows(rates, 0, split)
    x_te, y_te = build_windows(rates, split, total)

    params = init_params(seed)

    def loss_fn(p, x, y):
        err = y - forward_batch(p, x)
        return jnp.mean(jnp.maximum(TAU * err, (TAU - 1.0) * err))

    grad_fn = jax.jit(jax.value_and_grad(loss_fn))

    # Manual Adam (optax-free; build path only).
    m = jax.tree_util.tree_map(jnp.zeros_like, params)
    v = jax.tree_util.tree_map(jnp.zeros_like, params)
    b1, b2, eps = 0.9, 0.999, 1e-8

    rng = np.random.default_rng(seed)
    n = x_tr.shape[0]
    first_loss = None
    for it in range(steps):
        idx = rng.integers(0, n, size=min(batch, n))
        lval, g = grad_fn(params, jnp.asarray(x_tr[idx]), jnp.asarray(y_tr[idx]))
        if first_loss is None:
            first_loss = float(lval)
        t = it + 1
        m = jax.tree_util.tree_map(lambda a, b_: b1 * a + (1 - b1) * b_, m, g)
        v = jax.tree_util.tree_map(lambda a, b_: b2 * a + (1 - b2) * b_ ** 2, v, g)
        lr_t = lr * np.sqrt(1 - b2 ** t) / (1 - b1 ** t)
        params = jax.tree_util.tree_map(
            lambda p_, m_, v_: p_ - lr_t * m_ / (jnp.sqrt(v_) + eps),
            params, m, v)
        if it % 100 == 0:
            log(f"  predictor step {it}: loss {float(lval):.5f}")

    pred_te = np.asarray(forward_batch(params, jnp.asarray(x_te)))
    test_smape = smape(pred_te * SCALE, y_te * SCALE)
    params_np = {k: np.asarray(v_, np.float32) for k, v_ in params.items()}
    metrics = {
        "first_loss": first_loss,
        "final_loss": float(loss_fn(params, jnp.asarray(x_tr[:512]),
                                    jnp.asarray(y_tr[:512]))),
        "test_smape_pct": test_smape,
        "train_windows": int(n),
        "test_windows": int(x_te.shape[0]),
    }
    return params_np, metrics
