"""L1 Pallas kernel: block-tiled matmul.

This is the compute hot-spot of every synthetic model variant (the MLP
towers in model.py) — the IPA-equivalent of the conv/attention GEMMs
inside YOLOv5/ResNet/RoBERTa.

TPU-shaped design (see DESIGN.md §Hardware-Adaptation):
  * 3-D grid over (M/bm, N/bn, K/bk); the K axis is the innermost,
    sequential ("arbitrary") dimension so the output block stays resident
    in VMEM across the K sweep — the BlockSpec index_map expresses the
    HBM<->VMEM schedule that a CUDA kernel would express with threadblock
    tiling + shared-memory staging.
  * Accumulation happens in the f32 output block (revisited across k),
    with an @pl.when(k == 0) zero-init — the classic MXU accumulate
    pattern.
  * Default tiles are MXU-shaped (128x128) but are clamped to the operand
    shape so batch-1 inference (M=1) still works.

interpret=True is mandatory on CPU PJRT: real TPU lowering emits a Mosaic
custom-call that the CPU plugin cannot execute.  Correctness is pinned to
the pure-jnp oracle in ref.py by python/tests/test_kernel.py.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _matmul_kernel(x_ref, w_ref, o_ref, *, nk: int):
    """One (i, j, k) grid step: o[i,j] (+)= x[i,k] @ w[k,j]."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    # f32 accumulation regardless of operand dtype (MXU-style).
    acc = jnp.dot(
        x_ref[...].astype(jnp.float32),
        w_ref[...].astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )
    o_ref[...] += acc.astype(o_ref.dtype)


def _clamp_tile(dim: int, tile: int) -> int:
    """Largest divisor of `dim` that is <= tile (dims here are powers of two
    times 16, so walking down powers of two terminates quickly)."""
    t = min(tile, dim)
    while dim % t != 0:
        t -= 1
    return t


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk"))
def matmul(x: jax.Array, w: jax.Array, *, bm: int = 128, bn: int = 128,
           bk: int = 128) -> jax.Array:
    """Block-tiled Pallas matmul: x[M,K] @ w[K,N] -> [M,N] (f32 accum).

    Tile sizes are clamped to divisors of the operand dims; use
    tile-friendly shapes (multiples of 16 or powers of two) for the
    intended schedule.
    """
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, f"contraction mismatch {x.shape} @ {w.shape}"
    bm = _clamp_tile(m, bm)
    bn = _clamp_tile(n, bn)
    bk = _clamp_tile(k, bk)
    nk = k // bk

    out_dtype = jnp.promote_types(x.dtype, w.dtype)
    grid = (m // bm, n // bn, nk)
    return pl.pallas_call(
        functools.partial(_matmul_kernel, nk=nk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(x, w)


def linear(x: jax.Array, w: jax.Array, b: jax.Array, *, activation=None,
           bm: int = 128, bn: int = 128, bk: int = 128) -> jax.Array:
    """Affine layer on the Pallas matmul: act(x @ w + b)."""
    y = matmul(x, w, bm=bm, bn=bn, bk=bk) + b
    if activation is not None:
        y = activation(y)
    return y


def vmem_bytes(bm: int, bn: int, bk: int, dtype_bytes: int = 4) -> int:
    """VMEM footprint of one grid step (x tile + w tile + out tile).

    Used by the §Perf analysis to check the schedule against the ~16 MiB
    VMEM budget of a TPU core.
    """
    return dtype_bytes * (bm * bk + bk * bn + bm * bn)


def mxu_utilization(bm: int, bn: int, bk: int, mxu: int = 128) -> float:
    """Fraction of MXU lanes a (bm,bn,bk) tile keeps busy, as an estimate
    for the real-TPU efficiency of this schedule (interpret-mode wallclock
    is NOT a TPU proxy)."""
    eff_m = min(bm, mxu) / mxu
    eff_n = min(bn, mxu) / mxu
    eff_k = min(bk, mxu) / mxu
    return eff_m * eff_n * eff_k
