"""Pure-jnp oracles for the L1 Pallas kernels.

These are the CORE correctness signal: python/tests/test_kernel.py sweeps
shapes/dtypes with hypothesis and asserts the Pallas kernels match these
references to tight tolerances.  They are also used by model.py's
reference tower (which the AOT check values are computed from).
"""

import jax
import jax.numpy as jnp


def ref_matmul(x: jax.Array, w: jax.Array) -> jax.Array:
    """x[M,K] @ w[K,N] with f32 accumulation, like the Pallas kernel."""
    out_dtype = jnp.promote_types(x.dtype, w.dtype)
    y = jnp.dot(x.astype(jnp.float32), w.astype(jnp.float32),
                preferred_element_type=jnp.float32)
    return y.astype(out_dtype)


def ref_linear(x, w, b, activation=None):
    y = ref_matmul(x, w) + b
    if activation is not None:
        y = activation(y)
    return y


def ref_lstm_cell(x, h, c, wx, wh, b):
    """Reference LSTM cell, gate order (i, f, g, o) — mirrors lstm_cell.py."""
    gates = (x.astype(jnp.float32) @ wx.astype(jnp.float32)
             + h.astype(jnp.float32) @ wh.astype(jnp.float32)
             + b.astype(jnp.float32))
    hidden = h.shape[-1]
    i = jax.nn.sigmoid(gates[:, 0 * hidden:1 * hidden])
    f = jax.nn.sigmoid(gates[:, 1 * hidden:2 * hidden])
    g = jnp.tanh(gates[:, 2 * hidden:3 * hidden])
    o = jax.nn.sigmoid(gates[:, 3 * hidden:4 * hidden])
    c_new = f * c.astype(jnp.float32) + i * g
    h_new = o * jnp.tanh(c_new)
    return h_new.astype(h.dtype), c_new.astype(c.dtype)


def ref_tower(x, params, activation=jax.nn.relu):
    """Reference MLP tower: relu(x@W+b) per layer, linear last layer.

    `params` is a flat list [W1, b1, W2, b2, ...] matching model.py.
    """
    n_layers = len(params) // 2
    for li in range(n_layers):
        w, b = params[2 * li], params[2 * li + 1]
        x = ref_linear(x, w, b,
                       activation=activation if li < n_layers - 1 else None)
    return x
