"""L1 Pallas kernel: fused LSTM cell.

One cell step of the IPA load predictor (§3 "Predictor"): the two gate
GEMMs, the bias add, all four gate nonlinearities, and the state update
are fused into a single kernel so the (tiny) recurrent state never leaves
VMEM between the matmuls and the elementwise tail — the TPU equivalent of
the fused-gate CUDA LSTM kernels in cuDNN.

Shapes are small (hidden=32 for the predictor) so a single-block kernel
(no grid) is the right schedule; the block IS the VMEM tile.

interpret=True for CPU PJRT; oracle in ref.py.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _lstm_cell_kernel(x_ref, h_ref, c_ref, wx_ref, wh_ref, b_ref,
                      h_out_ref, c_out_ref):
    x = x_ref[...].astype(jnp.float32)
    h = h_ref[...].astype(jnp.float32)
    c = c_ref[...].astype(jnp.float32)

    gates = (
        jnp.dot(x, wx_ref[...].astype(jnp.float32),
                preferred_element_type=jnp.float32)
        + jnp.dot(h, wh_ref[...].astype(jnp.float32),
                  preferred_element_type=jnp.float32)
        + b_ref[...].astype(jnp.float32)
    )
    hidden = h.shape[-1]
    i = jax.nn.sigmoid(gates[:, 0 * hidden:1 * hidden])
    f = jax.nn.sigmoid(gates[:, 1 * hidden:2 * hidden])
    g = jnp.tanh(gates[:, 2 * hidden:3 * hidden])
    o = jax.nn.sigmoid(gates[:, 3 * hidden:4 * hidden])

    c_new = f * c + i * g
    h_new = o * jnp.tanh(c_new)
    h_out_ref[...] = h_new.astype(h_out_ref.dtype)
    c_out_ref[...] = c_new.astype(c_out_ref.dtype)


@jax.jit
def lstm_cell(x, h, c, wx, wh, b):
    """Fused LSTM cell step.

    Args:
      x:  [B, I] input at time t
      h:  [B, H] hidden state
      c:  [B, H] cell state
      wx: [I, 4H] input->gates weights (gate order: i, f, g, o)
      wh: [H, 4H] hidden->gates weights
      b:  [4H]   gate bias
    Returns:
      (h', c') each [B, H]
    """
    batch, hidden = h.shape
    return pl.pallas_call(
        _lstm_cell_kernel,
        out_shape=(
            jax.ShapeDtypeStruct((batch, hidden), h.dtype),
            jax.ShapeDtypeStruct((batch, hidden), c.dtype),
        ),
        interpret=True,
    )(x, h, c, wx, wh, b)
