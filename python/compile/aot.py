"""AOT compiler: lower every L2 graph to HLO text + write the manifest.

This is the single build-time entry point (`make artifacts`).  Python
never runs again after this: the Rust coordinator loads the HLO text via
`HloModuleProto::from_text_file` on the PJRT CPU client.

Interchange format is HLO *text*, not `.serialize()` — the image's
xla_extension 0.5.1 rejects jax>=0.5 protos with 64-bit instruction ids;
the text parser reassigns ids (see /opt/xla-example/README.md).

Outputs (under --out-dir, default ../artifacts):
  variants/<stage>.<name>_b<batch>.hlo.txt   29 variants x 7 batch sizes
  predictor/lstm.hlo.txt                     trained LSTM forward pass
  manifest.json                              index + check values + metrics
"""

import argparse
import json
import os
import sys
import time

import jax
import numpy as np
from jax._src.lib import xla_client as xc

from . import model, predictor, registry


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    # print_large_constants=True: the default printer elides big literals
    # as "{...}", which the rust-side HLO parser reads as zeros — the
    # baked LSTM weights would silently vanish.
    return comp.as_hlo_text(print_large_constants=True)


def lower_variant(spec: registry.VariantSpec, batch: int) -> str:
    fwd = model.make_forward(spec, batch)
    args = [model.input_spec(spec, batch)] + model.param_specs(spec)
    lowered = jax.jit(fwd).lower(*args)
    return to_hlo_text(lowered)


def lower_predictor(params_np) -> str:
    fwd = predictor.make_export_forward(params_np)
    spec = jax.ShapeDtypeStruct((1, predictor.HISTORY), np.float32)
    lowered = jax.jit(fwd).lower(spec)
    return to_hlo_text(lowered)


def emit_variants(out_dir, stages, batches, log):
    entries = []
    os.makedirs(os.path.join(out_dir, "variants"), exist_ok=True)
    todo = [v for v in registry.VARIANTS if v.stage_type in stages]
    for vi, spec in enumerate(todo):
        check = model.check_value(spec, batch=1)
        for batch in batches:
            name = f"{spec.key}_b{batch}.hlo.txt"
            path = os.path.join("variants", name)
            t0 = time.time()
            text = lower_variant(spec, batch)
            with open(os.path.join(out_dir, path), "w") as f:
                f.write(text)
            entries.append({
                "kind": "variant",
                "stage_type": spec.stage_type,
                "variant": spec.name,
                "key": spec.key,
                "batch": batch,
                "path": path,
                "hidden": spec.hidden,
                "layers": spec.layers,
                "params_m": spec.params_m,
                "base_alloc": spec.base_alloc,
                "accuracy": spec.accuracy,
                "flops": spec.flops(batch),
                # batch-1 check value (same params for every batch)
                "check_sum_b1": check,
            })
            log(f"[{vi + 1}/{len(todo)}] {spec.key} b={batch} "
                f"({time.time() - t0:.1f}s, {len(text)} chars)")
    return entries


def emit_predictor(out_dir, log, steps=400):
    os.makedirs(os.path.join(out_dir, "predictor"), exist_ok=True)
    log("training LSTM predictor ...")
    params_np, metrics = predictor.train(steps=steps, log=log)
    log(f"predictor test SMAPE: {metrics['test_smape_pct']:.2f}% "
        f"(paper: 6.6%)")
    text = lower_predictor(params_np)
    path = os.path.join("predictor", "lstm.hlo.txt")
    with open(os.path.join(out_dir, path), "w") as f:
        f.write(text)

    # Deterministic check: prediction on a fixed ramp window.
    window = np.linspace(5.0, 25.0, predictor.HISTORY,
                         dtype=np.float32)[None, :]
    fwd = predictor.make_export_forward(params_np)
    (check,) = fwd(window)
    entry = {
        "kind": "predictor",
        "path": path,
        "history": predictor.HISTORY,
        "horizon": predictor.HORIZON,
        "hidden": predictor.HIDDEN,
        "scale": predictor.SCALE,
        "metrics": metrics,
        "check_window": "linspace(5,25,120)",
        "check_pred": float(np.asarray(check)[0]),
    }
    return [entry]


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--stages", default="all",
                    help="comma-separated stage types, or 'all'")
    ap.add_argument("--batches", default=",".join(
        str(b) for b in registry.BATCH_SIZES))
    ap.add_argument("--skip-predictor", action="store_true")
    ap.add_argument("--skip-variants", action="store_true")
    ap.add_argument("--predictor-steps", type=int, default=400)
    ap.add_argument("--quiet", action="store_true")
    args = ap.parse_args(argv)

    log = (lambda *a: None) if args.quiet else (
        lambda *a: print(*a, file=sys.stderr, flush=True))

    stages = (set(registry.STAGE_THRESHOLDS) if args.stages == "all"
              else set(args.stages.split(",")))
    batches = [int(b) for b in args.batches.split(",")]

    os.makedirs(args.out_dir, exist_ok=True)
    t0 = time.time()
    entries = []
    if not args.skip_variants:
        entries += emit_variants(args.out_dir, stages, batches, log)
    if not args.skip_predictor:
        entries += emit_predictor(args.out_dir, log,
                                  steps=args.predictor_steps)

    manifest = {
        "version": 1,
        "generated_by": "python/compile/aot.py",
        "batch_sizes": batches,
        "artifacts": entries,
    }
    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    log(f"wrote {len(entries)} artifacts in {time.time() - t0:.1f}s "
        f"-> {args.out_dir}/manifest.json")


if __name__ == "__main__":
    main()
