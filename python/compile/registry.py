"""Model-variant registry (build-path mirror of rust/src/models/registry.rs).

The paper's Tables 7-14 define 29 model variants across 8 stage types.
Each variant here gets a *synthetic* compute graph (an MLP tower built on
the L1 Pallas matmul kernel) sized so that FLOPs ratios across variants of
a stage track the paper's parameter-count ratios.  Accuracy values are the
paper's static metadata — IPA treats accuracy as an offline property, so
carrying the published numbers is faithful to the system.

Hidden sizes are multiples of 16 to stay tile-friendly for the Pallas
kernel's BlockSpec grid.
"""

from dataclasses import dataclass, field
from typing import List, Tuple

# Batch sizes profiled/served, powers of two 1..64 (paper §4.2).
BATCH_SIZES = [1, 2, 4, 8, 16, 32, 64]

# Global scale knob: paper models are 1.9M-560M params on 96-core nodes;
# we target sub-ms..tens-of-ms CPU latency, so towers are ~100x smaller.
_HIDDEN_MULT = 20.0
_MIN_HIDDEN = 32
_MAX_HIDDEN = 512
_LAYERS = 3


def _hidden_for_params(params_m: float) -> int:
    """Map a paper parameter count (millions) to a tile-friendly hidden dim.

    FLOPs of the tower scale as layers*h^2, so h ~ sqrt(params) keeps the
    FLOPs ratio between two variants equal to their parameter ratio.
    """
    h = int(round((params_m ** 0.5) * _HIDDEN_MULT / 16.0)) * 16
    return max(_MIN_HIDDEN, min(_MAX_HIDDEN, h))


@dataclass(frozen=True)
class VariantSpec:
    """One model variant: identity + synthetic tower geometry."""

    stage_type: str          # e.g. "detect"
    name: str                # e.g. "yolov5n"
    params_m: float          # paper parameter count, millions
    base_alloc: int          # paper base allocation (CPU cores)
    accuracy: float          # paper accuracy metric (mAP/acc/1-WER/F1/...)
    hidden: int = 0          # synthetic tower width (derived)
    layers: int = _LAYERS

    def __post_init__(self):
        if self.hidden == 0:
            object.__setattr__(self, "hidden", _hidden_for_params(self.params_m))

    @property
    def key(self) -> str:
        return f"{self.stage_type}.{self.name}"

    def param_shapes(self) -> List[Tuple[Tuple[int, int], Tuple[int]]]:
        """[(W_shape, b_shape)] per layer; square tower in->hidden->...->hidden."""
        h = self.hidden
        return [((h, h), (h,)) for _ in range(self.layers)]

    def flops(self, batch: int) -> int:
        """MACs*2 for one forward pass at the given batch size."""
        return 2 * batch * self.layers * self.hidden * self.hidden


# Stage type -> RPS threshold `th` used by the Eq-1 base-allocation solver
# (paper Appendix A).
STAGE_THRESHOLDS = {
    "detect": 4,
    "classify": 4,
    "audio": 1,
    "qa": 1,
    "summarize": 5,
    "sentiment": 1,
    "langid": 4,
    "nmt": 4,
}

# Paper Tables 7-14, verbatim.
VARIANTS: List[VariantSpec] = [
    # Table 7: object detection (YOLOv5, mAP)
    VariantSpec("detect", "yolov5n", 1.9, 1, 45.7),
    VariantSpec("detect", "yolov5s", 7.2, 1, 56.8),
    VariantSpec("detect", "yolov5m", 21.2, 2, 64.1),
    VariantSpec("detect", "yolov5l", 46.5, 4, 67.3),
    VariantSpec("detect", "yolov5x", 86.7, 8, 68.9),
    # Table 8: object classification (ResNet, top-1 accuracy)
    VariantSpec("classify", "resnet18", 11.7, 1, 69.75),
    VariantSpec("classify", "resnet34", 21.8, 1, 73.31),
    VariantSpec("classify", "resnet50", 25.5, 1, 76.13),
    VariantSpec("classify", "resnet101", 44.54, 1, 77.37),
    VariantSpec("classify", "resnet152", 60.2, 2, 78.31),
    # Table 9: audio-to-text (1 - WER)
    VariantSpec("audio", "s2t-small", 29.5, 1, 58.72),
    VariantSpec("audio", "s2t-medium", 71.2, 2, 64.88),
    VariantSpec("audio", "wav2vec2-base", 94.4, 2, 66.15),
    VariantSpec("audio", "s2t-large", 267.8, 4, 66.74),
    VariantSpec("audio", "wav2vec2-large", 315.5, 8, 72.35),
    # Table 10: question answering (F1)
    VariantSpec("qa", "roberta-base", 277.45, 1, 77.14),
    VariantSpec("qa", "roberta-large", 558.8, 1, 83.79),
    # Table 11: summarization (ROUGE-L)
    VariantSpec("summarize", "distilbart-1-1", 82.9, 1, 32.26),
    VariantSpec("summarize", "distilbart-12-1", 221.5, 2, 33.37),
    VariantSpec("summarize", "distilbart-6-6", 229.9, 4, 35.73),
    VariantSpec("summarize", "distilbart-12-3", 255.1, 8, 36.39),
    VariantSpec("summarize", "distilbart-9-6", 267.7, 8, 36.61),
    VariantSpec("summarize", "distilbart-12-6", 305.5, 16, 36.99),
    # Table 12: sentiment analysis (accuracy)
    VariantSpec("sentiment", "distilbert", 66.9, 1, 79.6),
    VariantSpec("sentiment", "bert", 109.4, 1, 79.9),
    VariantSpec("sentiment", "roberta", 355.3, 1, 83.0),
    # Table 13: language identification (accuracy)
    VariantSpec("langid", "roberta-lid", 278.0, 1, 79.62),
    # Table 14: neural machine translation (BLEU)
    VariantSpec("nmt", "opus-mt-fr-en", 74.6, 4, 33.1),
    VariantSpec("nmt", "opus-mt-big-fr-en", 230.6, 8, 34.4),
]


def variants_of(stage_type: str) -> List[VariantSpec]:
    return [v for v in VARIANTS if v.stage_type == stage_type]


def by_key(key: str) -> VariantSpec:
    for v in VARIANTS:
        if v.key == key:
            return v
    raise KeyError(key)


# The five paper pipelines (Figure 6), stage types in order.
PIPELINES = {
    "video": ["detect", "classify"],
    "audio-qa": ["audio", "qa"],
    "audio-sent": ["audio", "sentiment"],
    "sum-qa": ["summarize", "qa"],
    "nlp": ["langid", "summarize", "nmt"],
}
