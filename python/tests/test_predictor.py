"""LSTM predictor tests: training convergence, export-path equivalence
(Pallas cell vs jnp cell), held-out quality."""

import numpy as np
import pytest

from compile import predictor, tracegen


@pytest.fixture(scope="module")
def trained():
    # Small budget: enough to beat the untrained baseline decisively.
    return predictor.train(steps=120, batch=128)


def test_training_reduces_loss(trained):
    params, metrics = trained
    assert metrics["final_loss"] < metrics["first_loss"] * 0.5, metrics


def test_heldout_smape_reasonable(trained):
    _, metrics = trained
    # paper: 6.6% (MSE loss, smoother Twitter trace).  Our pinball-loss
    # predictor intentionally over-predicts peaks (TAU=0.8), trading
    # SMAPE for fewer under-provisioning windows — keep the same order
    # of magnitude.
    assert metrics["test_smape_pct"] < 40.0, metrics


def test_export_forward_matches_training_forward(trained):
    """The Pallas-cell export path must agree with the jnp training path."""
    import jax.numpy as jnp

    params, _ = trained
    fwd = predictor.make_export_forward(params)
    rng = np.random.default_rng(1)
    for _ in range(3):
        window = rng.uniform(2, 40, predictor.HISTORY).astype(np.float32)
        (got,) = fwd(window[None, :])
        want = predictor.forward_batch(
            {k: jnp.asarray(v) for k, v in params.items()},
            jnp.asarray(window[None, :] / predictor.SCALE),
        ) * predictor.SCALE
        np.testing.assert_allclose(
            np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-3)


def test_prediction_scale_sane(trained):
    params, _ = trained
    fwd = predictor.make_export_forward(params)
    flat = np.full((1, predictor.HISTORY), 20.0, np.float32)
    (p,) = fwd(flat)
    # steady 20 RPS -> prediction in the vicinity of 20 (pinball loss
    # biases upward by design)
    assert 5.0 < float(np.asarray(p)[0]) < 50.0


def test_windows_construction():
    rates = list(range(200))
    x, y = predictor.build_windows(rates, 0, 200, stride=10)
    assert x.shape[1] == predictor.HISTORY
    assert len(x) == len(y)
    # target is the max of the following horizon
    t0 = predictor.HISTORY
    assert y[0] * predictor.SCALE == max(rates[t0:t0 + predictor.HORIZON])


def test_smape_metric():
    assert predictor.smape(np.array([10.0]), np.array([10.0])) == 0.0
    assert predictor.smape(np.array([11.0]), np.array([10.0])) == \
        pytest.approx(100.0 / 10.5)


def test_train_test_split_no_overlap():
    total = (predictor.TRAIN_DAYS + predictor.TEST_DAYS) * tracegen.DAY_SECONDS
    split = predictor.TRAIN_DAYS * tracegen.DAY_SECONDS
    rates = tracegen.generate("composite", total, predictor.TRACE_SEED)
    x_tr, _ = predictor.build_windows(rates, 0, split)
    x_te, _ = predictor.build_windows(rates, split, total)
    assert len(x_tr) > 0 and len(x_te) > 0
    # last training window ends before the first test window starts
    assert split <= total
