"""AOT pipeline tests: lowering produces loadable HLO text, the manifest
is consistent, and large constants are never elided."""

import json
import os

import numpy as np
import pytest

from compile import aot, model, registry


@pytest.fixture(scope="module")
def small_manifest(tmp_path_factory):
    out = tmp_path_factory.mktemp("artifacts")
    aot.main([
        "--out-dir", str(out),
        "--stages", "qa",
        "--batches", "1,8",
        "--skip-predictor",
        "--quiet",
    ])
    return out


def test_emits_expected_files(small_manifest):
    files = sorted(os.listdir(small_manifest / "variants"))
    assert files == [
        "qa.roberta-base_b1.hlo.txt",
        "qa.roberta-base_b8.hlo.txt",
        "qa.roberta-large_b1.hlo.txt",
        "qa.roberta-large_b8.hlo.txt",
    ]


def test_manifest_consistent(small_manifest):
    m = json.loads((small_manifest / "manifest.json").read_text())
    arts = [a for a in m["artifacts"] if a["kind"] == "variant"]
    assert len(arts) == 4
    for a in arts:
        assert (small_manifest / a["path"]).exists()
        spec = registry.by_key(a["key"])
        assert a["hidden"] == spec.hidden
        assert a["accuracy"] == spec.accuracy
        assert a["flops"] == spec.flops(a["batch"])
        assert np.isfinite(a["check_sum_b1"])


def test_hlo_text_structure(small_manifest):
    text = (small_manifest / "variants" / "qa.roberta-base_b1.hlo.txt").read_text()
    assert text.startswith("HloModule")
    assert "ENTRY" in text
    # weights are runtime parameters, not baked constants
    assert text.count("parameter(") >= 7  # x + 3x(W, b)
    assert "{...}" not in text, "elided constants would break the rust parser"


def test_lowering_batch_shapes():
    spec = registry.by_key("qa.roberta-base")
    t1 = aot.lower_variant(spec, 1)
    t8 = aot.lower_variant(spec, 8)
    assert f"f32[1,{spec.hidden}]" in t1
    assert f"f32[8,{spec.hidden}]" in t8


def test_check_value_matches_manifest(small_manifest):
    m = json.loads((small_manifest / "manifest.json").read_text())
    a = next(x for x in m["artifacts"]
             if x["kind"] == "variant" and x["key"] == "qa.roberta-base")
    spec = registry.by_key("qa.roberta-base")
    assert a["check_sum_b1"] == pytest.approx(model.check_value(spec), rel=1e-6)
