"""L1 kernel correctness: Pallas vs the pure-jnp oracles (ref.py).

This is the core correctness signal of the compile path — hypothesis
sweeps shapes and dtypes, assert_allclose against the reference.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.lstm_cell import lstm_cell
from compile.kernels.matmul import linear, matmul, mxu_utilization, vmem_bytes

RNG = np.random.default_rng(0)


def rand(shape, dtype=np.float32, scale=1.0):
    return (RNG.standard_normal(shape) * scale).astype(dtype)


# ---------------------------------------------------------------------------
# matmul
# ---------------------------------------------------------------------------

dims = st.sampled_from([16, 32, 48, 64, 128])
batches = st.sampled_from([1, 2, 4, 8, 16, 32, 64])


@settings(max_examples=25, deadline=None)
@given(m=batches, k=dims, n=dims)
def test_matmul_matches_ref_f32(m, k, n):
    x, w = rand((m, k)), rand((k, n))
    got = np.asarray(matmul(jnp.asarray(x), jnp.asarray(w)))
    want = np.asarray(ref.ref_matmul(x, w))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@settings(max_examples=10, deadline=None)
@given(m=batches, k=dims, n=dims)
def test_matmul_bf16_loose(m, k, n):
    x = rand((m, k)).astype(jnp.bfloat16)
    w = rand((k, n)).astype(jnp.bfloat16)
    got = np.asarray(matmul(jnp.asarray(x), jnp.asarray(w)).astype(jnp.float32))
    want = np.asarray(ref.ref_matmul(x, w).astype(jnp.float32))
    # bf16 storage, f32 accumulation: tolerances follow bf16 mantissa
    np.testing.assert_allclose(got, want, rtol=5e-2, atol=5e-1)


@settings(max_examples=10, deadline=None)
@given(
    m=batches,
    k=dims,
    n=dims,
    bm=st.sampled_from([8, 32, 128]),
    bk=st.sampled_from([16, 64, 128]),
    bn=st.sampled_from([16, 64, 128]),
)
def test_matmul_tile_invariance(m, k, n, bm, bk, bn):
    """Result must not depend on the BlockSpec tiling (up to f32
    accumulation-order noise: different bk splits sum in different
    orders)."""
    x, w = rand((m, k)), rand((k, n))
    a = np.asarray(matmul(jnp.asarray(x), jnp.asarray(w), bm=bm, bk=bk, bn=bn))
    b = np.asarray(matmul(jnp.asarray(x), jnp.asarray(w)))
    np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-4)


def test_matmul_rejects_mismatched_contraction():
    with pytest.raises(AssertionError):
        matmul(jnp.zeros((4, 32)), jnp.zeros((16, 8)))


def test_linear_applies_bias_and_activation():
    x, w = rand((4, 32)), rand((32, 16))
    b = rand((16,))
    got = np.asarray(linear(jnp.asarray(x), jnp.asarray(w), jnp.asarray(b),
                            activation=jax.nn.relu))
    want = np.asarray(ref.ref_linear(x, w, b, activation=jax.nn.relu))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)
    assert (got >= 0).all()


def test_vmem_and_mxu_estimates():
    # §Perf helpers: sanity of the analytic schedule estimators.
    assert vmem_bytes(128, 128, 128) == 4 * 3 * 128 * 128
    assert mxu_utilization(128, 128, 128) == 1.0
    assert mxu_utilization(8, 128, 128) == pytest.approx(8 / 128)
    assert vmem_bytes(128, 128, 128) < 16 * 2 ** 20, "fits VMEM budget"


# ---------------------------------------------------------------------------
# lstm_cell
# ---------------------------------------------------------------------------

@settings(max_examples=15, deadline=None)
@given(
    b=st.sampled_from([1, 2, 4]),
    i=st.sampled_from([1, 4, 8]),
    h=st.sampled_from([16, 32, 64]),
)
def test_lstm_cell_matches_ref(b, i, h):
    x = rand((b, i))
    hh = rand((b, h))
    c = rand((b, h))
    wx = rand((i, 4 * h), scale=0.3)
    wh = rand((h, 4 * h), scale=0.3)
    bias = rand((4 * h,), scale=0.1)
    got_h, got_c = lstm_cell(*map(jnp.asarray, (x, hh, c, wx, wh, bias)))
    want_h, want_c = ref.ref_lstm_cell(x, hh, c, wx, wh, bias)
    np.testing.assert_allclose(np.asarray(got_h), np.asarray(want_h),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(got_c), np.asarray(want_c),
                               rtol=1e-5, atol=1e-5)


def test_lstm_cell_state_bounds():
    # h = o * tanh(c') is bounded by (0,1)*(-1,1)
    x = rand((2, 1))
    h = rand((2, 32))
    c = rand((2, 32))
    wx = rand((1, 128))
    wh = rand((32, 128))
    b = rand((128,))
    got_h, _ = lstm_cell(*map(jnp.asarray, (x, h, c, wx, wh, b)))
    assert np.abs(np.asarray(got_h)).max() <= 1.0


def test_lstm_cell_gate_order_is_ifgo():
    """A huge forget-gate bias must preserve the cell state."""
    b_, h_ = 1, 16
    x = np.zeros((b_, 1), np.float32)
    h = np.zeros((b_, h_), np.float32)
    c = np.full((b_, h_), 0.7, np.float32)
    wx = np.zeros((1, 4 * h_), np.float32)
    wh = np.zeros((h_, 4 * h_), np.float32)
    bias = np.zeros(4 * h_, np.float32)
    bias[h_:2 * h_] = 25.0  # forget gate -> 1
    _, c2 = lstm_cell(*map(jnp.asarray, (x, h, c, wx, wh, bias)))
    np.testing.assert_allclose(np.asarray(c2), c, rtol=1e-5)
