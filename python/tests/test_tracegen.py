"""Workload generator tests (python side of the determinism contract)."""

import numpy as np
import pytest

from compile import tracegen


def test_deterministic():
    a = tracegen.generate("bursty", 300, 42)
    b = tracegen.generate("bursty", 300, 42)
    assert a == b
    assert a != tracegen.generate("bursty", 300, 43)


def test_pinned_values_for_rust_twin():
    """These exact values are asserted by rust tracegen tests — if this
    test changes, update rust/src/workload/tracegen.rs too."""
    r = tracegen.generate("bursty", 50, 42)
    assert r[0] == pytest.approx(7.3198207857538407, abs=0)
    assert r[4] == pytest.approx(8.736456153093064, abs=0)
    c = tracegen.generate("composite", 30, 0x77177E2A)
    assert c[0] == pytest.approx(4.0840338748544189, abs=0)


def test_pattern_shapes():
    lo = np.mean(tracegen.generate("steady_low", 1000, 1))
    hi = np.mean(tracegen.generate("steady_high", 1000, 1))
    assert hi > lo + 15
    fl = tracegen.generate("fluctuating", 600, 2)
    assert max(fl) > 22 and min(fl) < 9
    bu = tracegen.generate("bursty", 1200, 3)
    assert max(bu) > 24


def test_composite_diurnal():
    r = tracegen.generate("composite", 2 * tracegen.DAY_SECONDS, 5)
    midnight = np.mean(r[:100])
    mid = tracegen.DAY_SECONDS // 2
    midday = np.mean(r[mid - 50:mid + 50])
    assert midday > midnight + 5


def test_rates_floored_positive():
    for p in tracegen.PATTERNS:
        assert min(tracegen.generate(p, 200, 9)) >= 0.5


def test_bump_polynomial():
    assert tracegen.bump(0.0) == pytest.approx(0.0)
    assert tracegen.bump(0.5) == pytest.approx(1.0)
    assert tracegen.bump(1.25) == tracegen.bump(0.25)


def test_unknown_pattern_raises():
    with pytest.raises(ValueError):
        tracegen.generate("nope", 10, 0)
