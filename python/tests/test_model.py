"""L2 model-tower tests: registry geometry, Pallas tower vs oracle,
deterministic weights, check values."""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile import model, registry


def test_registry_counts():
    assert len(registry.VARIANTS) == 29
    assert len(registry.variants_of("detect")) == 5
    assert len(registry.variants_of("summarize")) == 6
    assert set(registry.PIPELINES) == {
        "video", "audio-qa", "audio-sent", "sum-qa", "nlp"}


def test_hidden_dims_monotone_in_params():
    for stage in registry.STAGE_THRESHOLDS:
        vs = registry.variants_of(stage)
        for a, b in zip(vs, vs[1:]):
            assert a.params_m < b.params_m
            assert a.hidden <= b.hidden


def test_hidden_dims_tile_friendly():
    for v in registry.VARIANTS:
        assert v.hidden % 16 == 0
        assert 32 <= v.hidden <= 512


@settings(max_examples=8, deadline=None)
@given(key=st.sampled_from([v.key for v in registry.VARIANTS]),
       batch=st.sampled_from([1, 4, 16]))
def test_tower_matches_reference(key, batch):
    spec = registry.by_key(key)
    params = [jnp.asarray(p) for p in model.make_params(spec)]
    x = jnp.asarray(model.check_input(spec, batch))
    (got,) = model.make_forward(spec, batch)(x, *params)
    (want,) = model.make_ref_forward(spec)(x, *params)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


def test_weights_deterministic_and_key_dependent():
    s1 = registry.by_key("detect.yolov5n")
    a = model.make_params(s1)
    b = model.make_params(s1)
    for t1, t2 in zip(a, b):
        np.testing.assert_array_equal(t1, t2)
    s2 = registry.by_key("detect.yolov5s")
    c = model.make_params(s2)
    assert not np.array_equal(a[0][: c[0].shape[0], : c[0].shape[1]], c[0][: a[0].shape[0], : a[0].shape[1]])


def test_check_value_stable():
    spec = registry.by_key("classify.resnet18")
    v1 = model.check_value(spec)
    v2 = model.check_value(spec)
    assert v1 == v2
    assert np.isfinite(v1)


def test_param_shapes_square_tower():
    spec = registry.by_key("qa.roberta-large")
    shapes = spec.param_shapes()
    assert len(shapes) == spec.layers
    for (w, b) in shapes:
        assert w == (spec.hidden, spec.hidden)
        assert b == (spec.hidden,)


def test_flops_ratio_tracks_params_ratio():
    # The sizing contract: FLOPs ratios approximate parameter ratios.
    vs = registry.variants_of("detect")
    small, large = vs[0], vs[-1]
    flops_ratio = large.flops(1) / small.flops(1)
    params_ratio = large.params_m / small.params_m
    assert 0.2 * params_ratio < flops_ratio < 5 * params_ratio


def test_splitmix_twin_values():
    """Pin the first SplitMix64-derived f32s (rust twin asserts the same
    stream in runtime::weights tests)."""
    v = model.splitmix64_fill(1, 3)
    mask = (1 << 64) - 1
    state = (1 + 0x9E3779B97F4A7C15) & mask
    z = state
    z = ((z ^ (z >> 30)) * 0xBF58476D1CE4E5B9) & mask
    z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & mask
    z = z ^ (z >> 31)
    expect0 = np.float32((z >> 40) / float(1 << 24)) - np.float32(0.5)
    assert v[0] == expect0
